"""Continuous-batching serving engine.

Replaces the fixed-batch loop (``launch.serve.FixedBatchServer``) with
request-level scheduling, the deployment path the paper's serving claim is
about: merged checkpoints route fewer, fuller expert groups through the
grouped kernel at identical arithmetic.

Design (decode dataflow details in DESIGN.md §7):

* **Slots.** The engine owns a persistent slotted KV cache
  (``[L, n_slots, s_max, nkv, hd]`` + per-slot ``pos``). A request occupies
  one slot from admission to completion; eviction just marks the slot free —
  stale rows are masked by the per-slot causal mask and overwritten in place
  by the next occupant (no copying, no reallocation).
* **Admission.** Pending requests sit in a heap ordered by
  ``(arrival_time, uid)`` (FIFO by arrival, O(log n) per op). At the top of
  every engine step each free slot claims the next due request, and all
  requests admitted together that share a prompt bucket are prefilled as ONE
  batch (padded to the next power of two to bound jit specializations) and
  inserted with one scatter — admission cost no longer scales with the burst
  size.
* **Decode.** The steady-state hot loop is DEVICE-RESIDENT: one jitted call
  runs ``decode_block`` (K) scanned decode steps with on-device sampling and
  per-slot stop flags; finished slots freeze in place and ride along. The
  host reads back one ``[K, B]`` token block per call instead of one token
  per step — host dispatches drop from ~2/token to ~2/(K·B) tokens.
  ``decode_block=1`` keeps the original step-at-a-time loop (the parity
  reference). With ``dispatch='gather'`` the decode-sized MoE layers skip
  the sort-based grouped path for the per-token gather kernel.
* **Stop conditions.** Per-request ``max_new_tokens`` and optional
  ``eos_token``, evaluated on device inside the fused block; freed slots
  admit at the next block boundary.

The clock is pluggable: ``clock='steps'`` interprets ``arrival_time`` in
decode-step units (deterministic — used by tests and the CPU benchmark),
``clock='wall'`` in seconds.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import model as MD
from repro.models.numerics import set_activation_mesh


@dataclasses.dataclass
class Request:
    """One generation request plus its engine-filled result/telemetry."""
    uid: int
    prompt: np.ndarray                  # [prompt_len] int32
    max_new_tokens: int
    eos_token: Optional[int] = None
    arrival_time: float = 0.0           # steps or seconds, per engine clock
    # engine-filled
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finished: Optional[float] = None
    finish_reason: Optional[str] = None  # "length" | "eos"

    @property
    def n_prompt(self) -> int:
        return int(self.prompt.shape[0])


@dataclasses.dataclass
class EngineConfig:
    arch: str = "qwen3-moe-30b-a3b"
    reduced: bool = True
    n_slots: int = 4
    s_max: int = 128                    # per-slot KV capacity
    prefill_buckets: Sequence[int] = (16, 32, 64)
    temperature: float = 0.0
    seed: int = 0
    # MoE dispatch for the serving path; "gather" = ragged with the decode
    # token counts specialized to the per-token gather kernel, "ragged"
    # forces the grouped kernel everywhere. None keeps the ModelConfig's.
    dispatch: Optional[str] = "gather"
    clock: str = "steps"                # "steps" | "wall"
    # fused decode block size K: decode steps per jitted call. 1 = the
    # step-at-a-time host loop (parity reference).
    decode_block: int = 8
    # prefill all due same-bucket requests as one batch (False = the
    # batch-of-1 admission loop, kept as the parity reference)
    batch_admission: bool = True
    # retrace/implicit-transfer guard mode (repro.analysis.trace_guard):
    # "count" surfaces violations in counters["retraces"] /
    # counters["implicit_transfers"], "strict" raises TraceGuardError,
    # "off" disables (plain jax.jit)
    trace_guard: str = "count"


class Engine:
    """Continuous-batching engine over a slotted KV cache."""

    def __init__(self, ec: EngineConfig, cfg=None, params=None):
        self.ec = ec
        cfg = cfg if cfg is not None else (
            configs.get(ec.arch).reduced() if ec.reduced
            else configs.get(ec.arch))
        if cfg.moe is not None and ec.dispatch is not None:
            moe = dataclasses.replace(cfg.moe, dispatch=ec.dispatch)
            if ec.dispatch == "gather":
                # the gather ceiling must cover the decode token count
                # (T = n_slots) or big-slot engines would silently fall back
                # to ragged on every decode step
                moe = dataclasses.replace(
                    moe, gather_max_tokens=max(moe.gather_max_tokens,
                                               ec.n_slots))
            cfg = cfg.replace(moe=moe)
        if cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                f"continuous batching serves token-only families "
                f"(dense/moe), not {cfg.family}")
        if ec.decode_block < 1:
            raise ValueError("decode_block must be >= 1")
        self.cfg = cfg
        mesh = make_host_mesh()
        set_activation_mesh(mesh)
        self.params = params if params is not None else MD.init(
            cfg, jax.random.PRNGKey(ec.seed))

        # host<->device crossing telemetry: device_calls counts jitted
        # dispatches, host_syncs counts device->host readbacks, tokens_out
        # counts generated tokens (dispatches-per-token = their ratio);
        # retraces/implicit_transfers are maintained by the trace guard
        # (DESIGN.md §9: both must stay 0 after warmup)
        self.counters: Dict[str, int] = {
            "device_calls": 0, "host_syncs": 0, "tokens_out": 0}
        from repro.analysis.trace_guard import TraceGuard
        self._guard = TraceGuard(ec.trace_guard, counters=self.counters)
        self._buckets = tuple(sorted(set(int(b) for b in ec.prefill_buckets)))
        # admission legitimately compiles one specialization per
        # (bucket, pow2-group) pair; decode entry points get exactly ONE
        self._admit_step = self._guard.wrap_jit(
            "slot_admit", ST.make_slot_admit(cfg),
            expected_traces=ST.admit_trace_budget(
                self._buckets, ec.s_max, ec.n_slots))
        self._decode = self._guard.wrap_jit(
            "slot_decode", ST.make_slot_decode(cfg), expected_traces=1)
        self._decode_multi = self._guard.wrap_jit(
            "slot_decode_multi",
            ST.make_slot_decode_multi(cfg, ec.decode_block, ec.temperature),
            expected_traces=1)
        self.cache = MD.init_slot_cache(cfg, ec.n_slots, ec.s_max)

        self._slot_req: List[Optional[Request]] = [None] * ec.n_slots
        self._last_tok = np.zeros((ec.n_slots,), np.int32)
        self._active = np.zeros((ec.n_slots,), bool)
        # heap of (arrival_time, uid, seq, Request): admission is FIFO by
        # arrival regardless of submission order, O(log n) per push/pop. The
        # monotonic ``seq`` breaks (arrival, uid) ties (submit() accepts
        # caller uids and never rejects reuse) so heapq never falls through
        # to comparing Request objects.
        self._pending: List[Tuple[float, int, int, Request]] = []
        self._seq = itertools.count()
        self._next_uid = 0
        self._step_count = 0
        self._t0: Optional[float] = None
        self._rng = np.random.default_rng(ec.seed)
        self._key = jax.random.PRNGKey(ec.seed + 1)   # fused-loop sampling
        # plan/report extras when booted via from_checkpoint
        self.artifact: Optional[dict] = None

    # ------------------------------------------------------------------ API

    @classmethod
    def from_checkpoint(cls, directory, ec: Optional[EngineConfig] = None,
                        step: int | None = None) -> "Engine":
        """Boot an engine directly from a ``save_compressed`` artifact.

        The artifact's own ModelConfig (including per-layer merged-expert
        counts) and parameters are used verbatim; ``ec`` only controls
        serving knobs (slots, buckets, dispatch — gather by default). The
        executed plan and compression report are exposed as
        ``engine.artifact``."""
        from repro.ckpt import checkpoint as CKPT
        cfg, params, artifact = CKPT.load_compressed(directory, step=step)
        if ec is None:
            ec = EngineConfig(arch=cfg.name, reduced=False)
        eng = cls(ec, cfg=cfg, params=params)
        eng.artifact = artifact
        return eng

    @property
    def n_active(self) -> int:
        return int(self._active.sum())

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    @property
    def idle(self) -> bool:
        return not self._pending and not self._active.any()

    @property
    def steps(self) -> int:
        """Decode steps taken so far (the 'steps' clock's current time)."""
        return self._step_count

    @property
    def host_dispatches_per_token(self) -> float:
        """Host<->device crossings (jit dispatches + readbacks) per
        generated token so far."""
        c = self.counters
        return (c["device_calls"] + c["host_syncs"]) / max(c["tokens_out"], 1)

    def _validate_request(self, prompt: np.ndarray,
                          max_new_tokens: int) -> None:
        """Reject requests that cannot be served, with the reason spelled
        out. A prompt must fit its prefill bucket AND leave generation room
        in the slot; anything longer used to be silently clamped by
        ``bucket_for`` and would corrupt the slot — now it is an error at
        SUBMISSION time (the only place the caller can react)."""
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        big = min(max(self._buckets, default=1), self.ec.s_max)
        if prompt.size > self.ec.s_max:
            raise ValueError(
                f"prompt length {prompt.size} cannot fit any prefill bucket: "
                f"the largest admissible bucket is capped by slot capacity "
                f"s_max={self.ec.s_max} (declared buckets "
                f"{tuple(self._buckets)} top out at {big}); shorten the "
                f"prompt or raise s_max")
        if prompt.size + max_new_tokens > self.ec.s_max:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds slot capacity s_max={self.ec.s_max}")

    def submit(self, prompt, max_new_tokens: int, eos_token: int | None = None,
               arrival_time: float = 0.0, uid: int | None = None) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        self._validate_request(prompt, max_new_tokens)
        if uid is None:
            uid = self._next_uid
        self._next_uid = max(self._next_uid, uid) + 1
        req = Request(uid=uid, prompt=prompt, max_new_tokens=max_new_tokens,
                      eos_token=eos_token, arrival_time=arrival_time)
        heapq.heappush(self._pending,
                       (req.arrival_time, req.uid, next(self._seq), req))
        return req

    def step(self, now: float | None = None) -> List[Request]:
        """Admit due requests, run ONE decode step, evict finished.
        Returns the requests that finished during this step. This is the
        step-at-a-time reference loop; :meth:`step_block` is the fused
        production path (``run`` picks by ``decode_block``)."""
        now = self._now() if now is None else now
        finished = self._admit(now)
        if self._active.any():
            # host->device conversions happen HERE, before the guard arms:
            # inside the guarded call every argument is already device-side
            toks = jnp.asarray(self._last_tok)
            act = jnp.asarray(self._active)
            logits, greedy, self.cache = self._guard.run(
                "slot_decode", self._decode, self.params, self.cache, toks,
                act)
            self.counters["device_calls"] += 1
            next_toks = self._sample(logits, greedy)
            self.counters["host_syncs"] += 1
            for slot in np.flatnonzero(self._active):
                req = self._slot_req[slot]
                tok = int(next_toks[slot])
                req.out_tokens.append(tok)
                self.counters["tokens_out"] += 1
                self._last_tok[slot] = tok
                if self._is_done(req, tok):
                    self._evict(slot, now)
                    finished.append(req)
        self._step_count += 1
        return finished

    def step_block(self, now: float | None = None) -> List[Request]:
        """Admit due requests, then run ``decode_block`` fused decode steps
        in ONE device call (DESIGN.md §7). Returns finished requests; their
        ``t_finished`` is the block-start clock plus the inner step they
        stopped at, so step accounting matches the per-step loop."""
        now = self._now() if now is None else now
        finished = self._admit(now)
        K = self.ec.decode_block
        if not self._active.any():
            # nothing to decode: advance one step so arrival admission keeps
            # fine-grained timing while the engine drains the future queue
            self._step_count += 1
            return finished
        n = self.ec.n_slots
        rem = np.zeros((n,), np.int32)
        eos = np.full((n,), -1, np.int32)
        slots = np.flatnonzero(self._active)
        for s in slots:
            req = self._slot_req[s]
            rem[s] = req.max_new_tokens - len(req.out_tokens)
            eos[s] = -1 if req.eos_token is None else req.eos_token
        self._key, sub = jax.random.split(self._key)
        # convert np inputs OUTSIDE the guarded region (explicit H2D); the
        # guarded fused block itself must touch the host zero times
        args = (self.params, self.cache, jnp.asarray(self._last_tok),
                jnp.asarray(self._active), jnp.asarray(rem),
                jnp.asarray(eos), sub)
        block, _, self.cache = self._guard.run(
            "slot_decode_multi", self._decode_multi, *args)
        self.counters["device_calls"] += 1
        block_np = np.asarray(block)        # ONE readback: [K, B, (tok, emit)]
        self.counters["host_syncs"] += 1
        for s in slots:
            req = self._slot_req[s]
            for j in range(K):
                if not block_np[j, s, 1]:
                    break
                tok = int(block_np[j, s, 0])
                req.out_tokens.append(tok)
                self.counters["tokens_out"] += 1
                self._last_tok[s] = tok
                if self._is_done(req, tok):
                    # steps clock: finish = block start + inner step. Wall
                    # clock has no per-inner-step timestamps (the block is
                    # one device call) — stamp the post-block wall time.
                    self._evict(s, now + j if self.ec.clock == "steps"
                                else self._now())
                    finished.append(req)
                    break
        self._step_count += K
        return finished

    def run(self, requests: Sequence[Request] | None = None) -> List[Request]:
        """Drive until every pending/submitted request completes."""
        if requests:
            # externally built Request objects get the same admission
            # contract as submit() — an oversized prompt must fail here, not
            # deep inside a prefill scatter. Validate the WHOLE batch before
            # enqueuing anything, so a rejected call leaves the engine
            # exactly as it found it (no half-enqueued requests).
            for r in requests:
                self._validate_request(np.asarray(r.prompt, np.int32),
                                       r.max_new_tokens)
            for r in requests:
                heapq.heappush(self._pending,
                               (r.arrival_time, r.uid, next(self._seq), r))
        advance = self.step_block if self.ec.decode_block > 1 else self.step
        done: List[Request] = []
        while not self.idle:
            done.extend(advance())
        return sorted(done, key=lambda r: r.uid)

    def expert_weight_dtypes(self) -> Tuple[str, str]:
        """(prefix, suffix/uncompressed) expert-table storage dtypes,
        inferred from the parameter tree ('int8' when a stack carries the
        quantized ``qexp`` subtree, DESIGN.md §8)."""
        def one(stack_key):
            stack = self.params.get(stack_key)
            if stack is None or "moe" not in stack:
                return "bf16"
            return "int8" if "qexp" in stack["moe"] else "bf16"
        return one("stack"), one("stack_c" if "stack_c" in self.params
                                 else "stack")

    def modeled_decode_traffic(self, pos: int | None = None) -> Dict[str, float]:
        """Analytic HBM bytes for one steady-state decode step of this
        engine (``launch.hlo_analysis.decode_traffic_model`` at the served
        config, weight dtypes read off the actual parameter tree). ``pos``
        defaults to mid-cache, matching :meth:`bench_decode`'s scratch
        state."""
        from repro.launch.hlo_analysis import decode_traffic_model
        prefix_dt, suffix_dt = self.expert_weight_dtypes()
        return decode_traffic_model(
            self.cfg, n_slots=self.ec.n_slots,
            pos=self.ec.s_max // 2 if pos is None else pos,
            weight_dtype=suffix_dt, prefix_weight_dtype=prefix_dt)

    def bench_decode(self, iters: int = 50,
                     k_steps: int | None = None) -> Dict[str, float]:
        """Steady-state decode throughput with every slot active, bypassing
        admission — isolates the jitted fused loop from scheduler overhead.

        Runs ``iters`` fused ``k_steps``-step blocks (default: the engine's
        ``decode_block``) on a scratch copy of the cache and returns
        ``{"tok_per_s", "dispatches_per_s", "host_dispatches_per_token",
        "k_steps"}`` — tokens/sec AND host dispatches/sec, since the fused
        loop improves the latter even where CPU model math dominates the
        former — plus the MODELED HBM traffic of the served config
        (``hbm_bytes_per_token``, ``moe_expert_bytes_per_token``) and the
        bandwidth-roofline ceiling it implies
        (``roofline_tok_per_s = 1/max(t_memory, t_compute)`` from
        ``hlo_analysis.roofline_terms``, with ``roofline_fraction`` = the
        measured tok/s against it; on CPU that fraction is noise — the
        modeled bytes are the portable signal). The ``pos`` reset needed to
        keep the scratch cache in bounds is fused INTO the jitted block (no
        host-side clamp op inside the timed loop, which previously added a
        dispatch per iteration and skewed the measurement)."""
        K = int(self.ec.decode_block if k_steps is None else k_steps)
        n = self.ec.n_slots
        s_max = self.ec.s_max
        if K >= s_max // 2:
            raise ValueError(f"k_steps={K} too large for s_max={s_max}")
        multi = ST.make_slot_decode_multi(self.cfg, K, self.ec.temperature)

        def block(params, cache, toks, act, rem, eos, key):
            # keep pos in bounds ON DEVICE: reset to mid-cache before the
            # scanned steps would run past the last slot row
            pos = cache["pos"]
            pos = jnp.where(pos + K >= s_max, s_max // 2, pos)
            return multi(params, dict(cache, pos=pos), toks, act, rem, eos,
                         key)

        fn = jax.jit(block)
        cache = jax.tree.map(jnp.copy, self.cache)
        cache["pos"] = jnp.full((n,), s_max // 2, jnp.int32)
        toks = jnp.zeros((n,), jnp.int32)
        act = jnp.ones((n,), bool)
        rem = jnp.full((n,), np.iinfo(np.int32).max // 2, jnp.int32)
        eos = jnp.full((n,), -1, jnp.int32)
        key = jax.random.PRNGKey(0)
        out, _, cache = fn(self.params, cache, toks, act, rem, eos, key)
        jax.block_until_ready(out)                                   # warm
        # the timed loop runs under transfer_guard("disallow"): a benchmark
        # number that silently included an implicit host transfer per block
        # would overstate dispatch savings — better to fail loudly here
        with jax.transfer_guard("disallow"):
            t0 = time.perf_counter()
            for _ in range(iters):
                out, _, cache = fn(self.params, cache, toks, act, rem, eos,
                                   key)
            jax.block_until_ready(out)
            dt = time.perf_counter() - t0
        tok_per_s = n * K * iters / dt
        from repro.launch.hlo_analysis import roofline_terms
        traffic = self.modeled_decode_traffic()
        terms = roofline_terms(traffic["flops_per_token"],
                               traffic["bytes_per_token"], 0.0)
        roof = 1.0 / max(terms["t_memory_s"], terms["t_compute_s"], 1e-30)
        return {
            "tok_per_s": tok_per_s,
            "dispatches_per_s": iters / dt,
            # 1 jitted call + 1 readback per block — same crossings-counting
            # definition as Engine.host_dispatches_per_token
            "host_dispatches_per_token": 2.0 / (n * K),
            "k_steps": K,
            # modeled traffic (TPU roofline target, not a host measurement)
            "hbm_bytes_per_token": traffic["bytes_per_token"],
            "moe_expert_bytes_per_token":
                traffic["moe_expert_bytes_per_token"],
            "roofline_tok_per_s": roof,
            "roofline_fraction": tok_per_s / roof,
        }

    # ------------------------------------------------------------ internals

    def _now(self) -> float:
        if self.ec.clock == "steps":
            return float(self._step_count)
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    def bucket_for(self, n: int) -> int:
        """Prefill pad length for an ``n``-token prompt (the jit
        specialization it will compile into). Clamped to ``s_max`` so a
        bucket never outgrows the slot it is inserted into; lengths beyond
        ``s_max`` have no admissible bucket and raise (``submit`` rejects
        them up front with the full context — this is the fail-closed
        backstop for callers probing bucket shapes directly)."""
        if n > self.ec.s_max:
            raise ValueError(
                f"no prefill bucket fits {n} tokens (s_max={self.ec.s_max})")
        for b in self._buckets:
            if n <= b:
                return min(b, self.ec.s_max)
        big = self._buckets[-1] if self._buckets else 1
        return min(-(-n // big) * big, self.ec.s_max)

    def _sample(self, logits, greedy) -> np.ndarray:
        if self.ec.temperature <= 0.0:
            return np.asarray(greedy)
        lg = np.asarray(logits, np.float64) / self.ec.temperature
        g = self._rng.gumbel(size=lg.shape)
        return np.argmax(lg + g, axis=-1).astype(np.int32)

    def _is_done(self, req: Request, tok: int) -> bool:
        if req.eos_token is not None and tok == req.eos_token:
            req.finish_reason = "eos"
            return True
        if len(req.out_tokens) >= req.max_new_tokens:
            req.finish_reason = "length"
            return True
        return False

    def _admit(self, now: float) -> List[Request]:
        """Fill free slots with due pending requests (prefill + insert +
        first token), batching same-bucket admissions. Returns requests that
        finish AT admission (e.g. max_new_tokens == 1)."""
        finished: List[Request] = []
        free = [s for s in range(self.ec.n_slots) if not self._active[s]]
        claimed: List[Tuple[Request, int]] = []
        while free and self._pending and self._pending[0][0] <= now:
            req = heapq.heappop(self._pending)[-1]
            claimed.append((req, free.pop(0)))
        if not claimed:
            return finished
        if self.ec.batch_admission:
            groups: Dict[int, List[Tuple[Request, int]]] = {}
            for req, slot in claimed:
                groups.setdefault(self.bucket_for(req.n_prompt),
                                  []).append((req, slot))
            for bucket in sorted(groups):
                self._admit_group(bucket, groups[bucket], now, finished)
        else:
            for req, slot in claimed:
                self._admit_group(self.bucket_for(req.n_prompt),
                                  [(req, slot)], now, finished)
        return finished

    def _admit_group(self, bucket: int, group: List[Tuple[Request, int]],
                     now: float, finished: List[Request]) -> None:
        """Prefill + insert + first token for one bucket's admissions as a
        single fused device call (``steps.make_slot_admit``).

        The batch is padded to the next power of two so admission compiles
        at most ``len(buckets) * (log2(n_slots)+1)`` specializations instead
        of one per (bucket, group-size) pair; pad rows carry an
        out-of-bounds slot index, which JAX scatter semantics drop, so they
        never touch the cache."""
        B = len(group)
        Bp = 1
        while Bp < B:
            Bp *= 2
        toks = np.zeros((Bp, bucket), np.int32)
        lengths = np.ones((Bp,), np.int32)
        slots = np.full((Bp,), self.ec.n_slots, np.int32)   # pads: OOB, dropped
        for i, (req, slot) in enumerate(group):
            toks[i, :req.n_prompt] = req.prompt
            lengths[i] = req.n_prompt
            slots[i] = slot
        logits, greedy, self.cache = self._admit_step(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(lengths),
            jnp.asarray(slots))
        self.counters["device_calls"] += 1
        first = self._sample(logits[:B], greedy[:B])
        self.counters["host_syncs"] += 1
        for i, (req, slot) in enumerate(group):
            tok = int(first[i])
            req.out_tokens.append(tok)
            self.counters["tokens_out"] += 1
            req.t_admitted = now
            req.t_first_token = now
            self._slot_req[slot] = req
            self._last_tok[slot] = tok
            self._active[slot] = True
            if self._is_done(req, tok):
                self._evict(slot, now)
                finished.append(req)

    def _evict(self, slot: int, now: float) -> None:
        req = self._slot_req[slot]
        if req is not None:
            req.t_finished = now
        self._slot_req[slot] = None
        self._active[slot] = False


# ---------------------------------------------------------------------------
# arrival traces
# ---------------------------------------------------------------------------

def poisson_trace(n_requests: int, rate: float, seed: int = 0) -> np.ndarray:
    """Cumulative Poisson-process arrival times (rate = requests per clock
    unit: decode steps or seconds, matching the engine clock)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), size=n_requests)
    return np.cumsum(gaps)
