"""Compatibility shim for ``hypothesis``.

The tier-1 suite must collect and run on bare containers where hypothesis is
not installed. When the real package is present we re-export it unchanged;
otherwise a tiny deterministic re-implementation of the subset used by the
tests (``given``, ``settings``, ``st.integers / sampled_from / booleans /
lists`` + ``.filter``) runs each property over seeded pseudo-random examples.

Usage in test modules (instead of ``from hypothesis import ...``):

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _DEFAULT_EXAMPLES = 10
    _MAX_REJECTS = 1000

    class _Strategy:
        def example(self, rng: random.Random):
            raise NotImplementedError

        def filter(self, pred):
            return _Filtered(self, pred)

    class _Filtered(_Strategy):
        def __init__(self, base, pred):
            self.base, self.pred = base, pred

        def example(self, rng):
            for _ in range(_MAX_REJECTS):
                v = self.base.example(rng)
                if self.pred(v):
                    return v
            raise RuntimeError("filter rejected too many examples")

    class _Integers(_Strategy):
        def __init__(self, min_value=0, max_value=2 ** 31 - 1):
            self.lo, self.hi = min_value, max_value

        def example(self, rng):
            return rng.randint(self.lo, self.hi)

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def example(self, rng):
            return rng.choice(self.elements)

    class _Booleans(_Strategy):
        def example(self, rng):
            return rng.random() < 0.5

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=10):
            self.elements = elements
            self.min_size, self.max_size = min_size, max_size

        def example(self, rng):
            n = rng.randint(self.min_size, self.max_size)
            return [self.elements.example(rng) for _ in range(n)]

    class _St:
        """Namespace mirroring ``hypothesis.strategies``."""
        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

        @staticmethod
        def booleans():
            return _Booleans()

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Lists(elements, min_size, max_size)

    st = _St()

    def given(*arg_strategies, **kw_strategies):
        """Deterministic fallback: run the test body over N seeded examples."""
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    drawn = tuple(s.example(rng) for s in arg_strategies)
                    drawn_kw = {k: s.example(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)
            # hide the strategy-filled parameters from pytest's fixture
            # resolution (functools.wraps exposes them via __wrapped__)
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
