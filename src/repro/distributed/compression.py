"""Gradient compression for slow (cross-pod DCN) links.

Two pieces:

* ``ef_compressed(opt, bits=8)`` — optimizer wrapper implementing
  ERROR-FEEDBACK quantization: the gradient is quantized to int8 (per-leaf
  max-abs scaling, stochastic rounding via a deterministic hash of the step),
  the quantization residual is accumulated into an ``ef`` state and added
  back next step. The inner optimizer only ever sees dequantized gradients —
  exactly what crosses the wire in the compressed-collective deployment.

* ``compressed_psum(x, axis)`` — a shard_map-compatible int8 all-reduce:
  quantize -> psum int32 -> dequantize. Moves 4x fewer bytes on the mapped
  axis; used for the ``pod`` axis where DCN bandwidth, not ICI, is the
  bottleneck (EXPERIMENTS.md §Perf, multi-pod iteration).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, _diffable, _is_float0

F32 = jnp.float32
I8_MAX = 127.0


def quantize(g, key):
    """Per-tensor max-abs int8 quantization with stochastic rounding."""
    g = g.astype(F32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / I8_MAX
    scaled = g / scale
    noise = jax.random.uniform(key, g.shape, F32) - 0.5
    q = jnp.clip(jnp.round(scaled + noise), -I8_MAX, I8_MAX).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(F32) * scale


def ef_compressed(opt: Optimizer, seed: int = 0) -> Optimizer:
    """Wrap ``opt`` with int8 error-feedback gradient compression."""

    def init(params):
        inner = opt.init(params)
        ef = jax.tree.map(
            lambda p: jnp.zeros(p.shape, F32) if _diffable(p)
            else jnp.zeros((), F32), params)
        return {"inner": inner, "ef": ef}

    def update(grads, state, params, step):
        base = jax.random.fold_in(jax.random.PRNGKey(seed), step)

        def compress(path_idx, g, r, p):
            if _is_float0(g) or not _diffable(p):
                return g, r
            key = jax.random.fold_in(base, path_idx)
            corrected = g.astype(F32) + r
            q, scale = quantize(corrected, key)
            deq = dequantize(q, scale)
            return deq, corrected - deq

        leaves_g, tdef = jax.tree_util.tree_flatten(grads)
        leaves_r = tdef.flatten_up_to(state["ef"])
        leaves_p = tdef.flatten_up_to(params)
        out = [compress(i, g, r, p) for i, (g, r, p)
               in enumerate(zip(leaves_g, leaves_r, leaves_p))]
        new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_ef = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        updates, inner = opt.update(new_g, state["inner"], params, step)
        return updates, {"inner": inner, "ef": new_ef}

    return Optimizer(init, update, state_factored=opt.state_factored)


def compressed_psum(x: jax.Array, axis: str, key) -> jax.Array:
    """int8-over-the-wire psum for use inside shard_map. Each participant
    quantizes its contribution; the int32 sum is exact; dequantization uses
    the max scale (all-reduced, 4 bytes)."""
    g = x.astype(F32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / I8_MAX
    scale = jax.lax.pmax(scale, axis)                 # shared scale
    noise = jax.random.uniform(key, g.shape, F32) - 0.5
    q = jnp.clip(jnp.round(g / scale + noise), -I8_MAX, I8_MAX
                 ).astype(jnp.int32)
    total = jax.lax.psum(q, axis)
    return total.astype(F32) * scale
