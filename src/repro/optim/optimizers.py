"""Pure-JAX optimizers (no optax dependency).

* ``adamw``     — default below ~100B params. States m, v mirror params.
* ``adafactor`` — factored second moment for the ≥100B configs (qwen1.5-110b,
  kimi-k2): states are O(sum of dims), not O(params), which is what makes
  trillion-parameter training fit the production mesh (DESIGN.md §6).
* global-norm clipping + cosine schedule built in via ``make_optimizer``.

State pytrees mirror the param tree structure (each param leaf maps to a dict
of state leaves), so the sharding rules for params transfer mechanically —
see launch/sharding.py::opt_state_pspecs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _diffable(p) -> bool:
    return jnp.issubdtype(jnp.asarray(p).dtype, jnp.floating)


def _is_float0(g) -> bool:
    return getattr(g, "dtype", None) == jax.dtypes.float0


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple]  # (grads, state, params, step) -> (new_params, new_state)
    state_factored: bool = False


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(F32)))
              for x in jax.tree.leaves(tree) if not _is_float0(x)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _clip(grads, max_norm: float):
    if not max_norm:
        return grads
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: x if _is_float0(x) else x * scale, grads)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = step.astype(F32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def apply_updates(params, updates):
    def one(p, u):
        if u is None or _is_float0(u) or not _diffable(p):
            return p    # non-differentiable leaves (e.g. MoE remap tables)
        return (p.astype(F32) + u).astype(p.dtype)
    return jax.tree.map(one, params, updates)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr=1e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          max_grad_norm=1.0, schedule=None) -> Optimizer:
    lr_fn = schedule or (lambda step: jnp.asarray(lr, F32))

    def init(params):
        def one(p):
            if not _diffable(p):
                return {"na": jnp.zeros((), F32)}
            return {"m": jnp.zeros(p.shape, F32),
                    "v": jnp.zeros(p.shape, F32)}
        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        grads = _clip(grads, max_grad_norm)
        t = step.astype(F32) + 1.0
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, s, p):
            if _is_float0(g) or not _diffable(p):
                return jnp.zeros((), F32), s
            g = g.astype(F32)
            m = b1 * s["m"] + (1 - b1) * g
            v = b2 * s["v"] + (1 - b2) * g * g
            u = -lr_t * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                         + weight_decay * p.astype(F32))
            return u, {"m": m, "v": v}

        flat = jax.tree.map(upd, grads, state, params,
                            is_leaf=lambda x: isinstance(x, dict) and ("m" in x or "na" in x))
        updates = jax.tree.map(lambda t2: t2[0], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda t2: t2[1], flat,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return updates, new_state

    return Optimizer(init, update, state_factored=False)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment)
# ---------------------------------------------------------------------------

def adafactor(lr=1e-2, decay=0.8, eps=1e-30, clip_threshold=1.0,
              max_grad_norm=1.0, schedule=None) -> Optimizer:
    lr_fn = schedule or (lambda step: jnp.asarray(lr, F32))

    def _factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params):
        def one(p):
            if not _diffable(p):
                return {"na": jnp.zeros((), F32)}
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], F32),          # row
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)}
            return {"v": jnp.zeros(p.shape, F32)}
        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        grads = _clip(grads, max_grad_norm)
        t = step.astype(F32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, s, p):
            if _is_float0(g) or not _diffable(p):
                return jnp.zeros((), F32), s
            g = g.astype(F32)
            g2 = g * g + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                         ) * vc[..., None, :]
                u = g * jax.lax.rsqrt(denom + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (Adafactor's RMS-based trust ratio)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_t * u, new_s

        flat = jax.tree.map(upd, grads, state, params,
                            is_leaf=lambda x: isinstance(x, dict) and
                            ("v" in x or "vr" in x or "na" in x))
        updates = jax.tree.map(lambda t2: t2[0], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda t2: t2[1], flat,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return updates, new_state

    return Optimizer(init, update, state_factored=True)


def sgd(lr=1e-2, max_grad_norm=0.0) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: {"_": jnp.zeros((), F32)}, params)

    def update(grads, state, params, step):
        grads = _clip(grads, max_grad_norm)
        return jax.tree.map(lambda g: -lr * g.astype(F32), grads), state

    return Optimizer(init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}[name](**kw)


def default_optimizer_for(param_count: int) -> str:
    """≥100B params -> factored states (DESIGN.md §6 memory plan)."""
    return "adafactor" if param_count >= 100e9 else "adamw"
