"""Continuous-batching engine: decode parity against the fixed-batch loop
(greedy, same seed — token-for-token), compressed and uncompressed, plus
scheduler semantics (admission order, slot reuse isolation, stop conditions).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import compress as CMP
from repro.launch.serve import FixedBatchServer, ServeConfig
from repro.models import model as MD
from repro.serving import Engine, EngineConfig, poisson_trace

ARCH = "qwen3-moe-30b-a3b"
B, P, NEW = 4, 16, 8


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get(ARCH).reduced()
    # serving path: ragged dispatch for BOTH loops so parity is exact
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="ragged"))
    params = MD.init(cfg, jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    ncfg, nparams, _ = CMP.compress_model(
        cfg, params, method="mergemoe",
        merged_experts=cfg.moe.n_experts // 2, split=0, batches=calib)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(B, P), dtype=np.int32)
    return cfg, params, ncfg, nparams, prompts


def _engine_out(cfg, params, prompts, n_slots=B, s_max=P + NEW + 4):
    eng = Engine(EngineConfig(arch=ARCH, n_slots=n_slots, s_max=s_max,
                              prefill_buckets=(P,)),
                 cfg=cfg, params=params)
    for i in range(prompts.shape[0]):
        eng.submit(prompts[i], max_new_tokens=NEW)
    done = eng.run()
    assert [r.uid for r in done] == list(range(prompts.shape[0]))
    return np.stack([np.asarray(r.out_tokens) for r in done])


@pytest.mark.parametrize("compressed", [False, True],
                         ids=["uncompressed", "mergemoe-m-half"])
def test_decode_parity_engine_vs_fixed_batch(setup, compressed):
    """Greedy continuous-batching output == fixed-batch output,
    token for token, through the ragged/grouped-kernel MoE path."""
    cfg, params, ncfg, nparams, prompts = setup
    c, p = (ncfg, nparams) if compressed else (cfg, params)
    fixed = FixedBatchServer(
        ServeConfig(arch=ARCH, batch_size=B, prompt_len=P,
                    max_new_tokens=NEW), cfg=c, params=p)
    ref = fixed.generate(prompts)
    out = _engine_out(c, p, prompts)
    np.testing.assert_array_equal(out, ref)


def test_slot_turnover_isolation(setup):
    """A request decoded in a busy 2-slot engine (slots recycled across
    requests, mixed prompt lengths) must be token-identical to the same
    request served alone — stale KV from evicted occupants never leaks."""
    cfg, params, ncfg, nparams, _ = setup
    rng = np.random.default_rng(3)
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=64,
                              prefill_buckets=(8, 16, 32)),
                 cfg=ncfg, params=nparams)
    reqs = []
    for i, (ln, arr) in enumerate(zip([5, 16, 9, 30, 12], [0, 0, 1, 2, 2])):
        reqs.append(eng.submit(
            rng.integers(0, cfg.vocab_size, size=ln, dtype=np.int32),
            max_new_tokens=4 + i, arrival_time=float(arr)))
    done = eng.run()
    assert len(done) == len(reqs)
    for r in done:
        solo = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=64,
                                   prefill_buckets=(8, 16, 32)),
                      cfg=ncfg, params=nparams)
        sr = solo.submit(r.prompt, max_new_tokens=r.max_new_tokens)
        solo.run()
        assert sr.out_tokens == r.out_tokens


def test_parity_split_stack(setup):
    """Compression with split > 0 leaves a prefix of uncompressed layers
    ('stack') ahead of the merged suffix ('stack_c'); the engine's prefill
    and decode must thread the slot cache through both."""
    cfg, params, _, _, prompts = setup
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(8), (4, 64),
                                           0, cfg.vocab_size)}]
    scfg, sparams, _ = CMP.compress_model(
        cfg, params, method="mergemoe",
        merged_experts=cfg.moe.n_experts // 2, split=1, batches=calib)
    assert "stack" in sparams and "stack_c" in sparams
    fixed = FixedBatchServer(
        ServeConfig(arch=ARCH, batch_size=B, prompt_len=P,
                    max_new_tokens=NEW), cfg=scfg, params=sparams)
    ref = fixed.generate(prompts)
    out = _engine_out(scfg, sparams, prompts)
    np.testing.assert_array_equal(out, ref)


def test_admission_respects_arrival_and_capacity(setup):
    cfg, params, _, _, _ = setup
    rng = np.random.default_rng(1)
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=48,
                              prefill_buckets=(8,)), cfg=cfg, params=params)
    # submitted OUT of arrival order: the queue must re-sort, so an early
    # submission with a late arrival never blocks a later-due request
    for i in (2, 0, 3, 1):
        eng.submit(rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32),
                   max_new_tokens=3, arrival_time=float(i), uid=i)
    done = eng.run()
    admitted = [r.t_admitted for r in done]      # done sorted by uid==arrival
    assert admitted == sorted(admitted)
    # FIFO by arrival; a request is never admitted before it arrives, and
    # with 2 slots the last two must wait for evictions. An admission step
    # yields two tokens (prefill logits + the same step's decode), so a
    # request occupies its slot for max_new_tokens - 2 further steps.
    for r in done:
        assert r.t_admitted >= r.arrival_time
        assert r.t_finished - r.t_admitted == max(0, r.max_new_tokens - 2)
    assert done[2].t_admitted >= done[0].t_finished


def test_stop_conditions(setup):
    """max_new_tokens == 1 finishes at admission; eos_token stops early."""
    cfg, params, _, _, _ = setup
    rng = np.random.default_rng(2)
    eng = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=48,
                              prefill_buckets=(8,)), cfg=cfg, params=params)
    prompt = rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
    one = eng.submit(prompt, max_new_tokens=1)
    eng.run()
    assert len(one.out_tokens) == 1 and one.finish_reason == "length"

    # find what greedy decodes first, then use it as the eos token
    probe = eng.submit(prompt, max_new_tokens=4)
    eng.run()
    eos = probe.out_tokens[1]
    stopped = eng.submit(prompt, max_new_tokens=10, eos_token=eos)
    eng.run()
    assert stopped.finish_reason == "eos"
    assert stopped.out_tokens == probe.out_tokens[:2]


def test_bucket_never_exceeds_slot_capacity(setup):
    """A prompt whose bucket rounds past s_max must still serve: the pad
    length is clamped to the slot size (regression — previously crashed in
    insert_slot's dynamic_update_slice)."""
    cfg, params, _, _, _ = setup
    eng = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=44,
                              prefill_buckets=(8, 16)), cfg=cfg, params=params)
    assert eng.bucket_for(40) == 44
    req = eng.submit(np.ones(40, np.int32), max_new_tokens=4)
    eng.run()
    assert len(req.out_tokens) == 4


def test_submit_validation(setup):
    cfg, params, _, _, _ = setup
    eng = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=16,
                              prefill_buckets=(8,)), cfg=cfg, params=params)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(12, np.int32), max_new_tokens=8)  # > s_max
    with pytest.raises(ValueError):
        eng.submit(np.zeros(0, np.int32), max_new_tokens=1)


def test_submit_rejects_unservable_prompts_at_boundaries(setup):
    """An oversized prompt must fail AT SUBMISSION with a clear error, not
    be silently clamped into a bucket it cannot fit (regression: bucket_for
    used to clamp to s_max unconditionally). Boundary sweep: a request
    consumes prompt + max_new - 1 KV rows (the final sampled token is never
    fed back), so the largest servable length is s_max - max_new_tokens + 1
    EXACTLY — the pre-fix check was off by one and rejected it."""
    cfg, params, _, _, _ = setup
    eng = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=32,
                              prefill_buckets=(8, 16)), cfg=cfg, params=params)
    # exactly fits: prompt + max_new == s_max + 1 consumes precisely s_max
    # KV rows — this submission RAISED before the off-by-one fix
    ok = eng.submit(np.ones(29, np.int32), max_new_tokens=4)
    eng.run()
    assert len(ok.out_tokens) == 4
    # one past the slot budget: would need s_max + 1 rows
    with pytest.raises(ValueError, match="slot capacity"):
        eng.submit(np.ones(30, np.int32), max_new_tokens=4)
    # longer than the slot itself: no bucket can ever fit it — the message
    # must say so (names the bucket ceiling and s_max)
    with pytest.raises(ValueError, match="cannot fit any prefill bucket"):
        eng.submit(np.ones(33, np.int32), max_new_tokens=1)
    # bucket_for itself fails closed past s_max...
    with pytest.raises(ValueError, match="no prefill bucket fits"):
        eng.bucket_for(33)
    assert eng.bucket_for(32) == 32       # ...and clamps at the boundary
    # run(requests=...) applies the same contract to hand-built requests,
    # and rejection is ATOMIC: a bad request anywhere in the batch leaves
    # the engine untouched — valid requests earlier in the list must not
    # stay enqueued on a failed call
    from repro.serving.engine import Request
    good = Request(uid=98, prompt=np.ones(8, np.int32), max_new_tokens=2)
    bad = Request(uid=99, prompt=np.ones(40, np.int32), max_new_tokens=1)
    with pytest.raises(ValueError, match="cannot fit any prefill bucket"):
        eng.run([good, bad])
    assert eng.idle                       # nothing leaked into the queue
    done = eng.run([good])                # the valid request serves cleanly
    assert [r.uid for r in done] == [98] and len(good.out_tokens) == 2


def test_int8_engine_serves_and_matches_ragged(setup):
    """Quantized expert tables (DESIGN.md §8) serve through the engine:
    int8 gather == int8 ragged token-for-token on a slot-turnover trace,
    and the modeled decode traffic reports the int8 byte diet."""
    from repro.core import quant as Q
    cfg, params, ncfg, nparams, _ = setup
    qparams = Q.quantize_model_experts(nparams)
    rng = np.random.default_rng(4)
    outs = {}
    for disp in ("gather", "ragged"):
        eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=64,
                                  prefill_buckets=(8, 16, 32),
                                  dispatch=disp),
                     cfg=ncfg, params=qparams)
        rng_l = np.random.default_rng(4)
        reqs = [eng.submit(rng_l.integers(0, cfg.vocab_size, size=l,
                                          dtype=np.int32),
                           max_new_tokens=4 + i)
                for i, l in enumerate([5, 16, 9, 30])]
        eng.run()
        outs[disp] = [r.out_tokens for r in reqs]
        assert eng.expert_weight_dtypes()[1] == "int8"
    assert outs["gather"] == outs["ragged"]
    # int8 engine models strictly fewer expert bytes than the bf16 engine
    e8 = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=64,
                             prefill_buckets=(8,)), cfg=ncfg, params=qparams)
    e16 = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=64,
                              prefill_buckets=(8,)), cfg=ncfg, params=nparams)
    t8, t16 = e8.modeled_decode_traffic(), e16.modeled_decode_traffic()
    assert t8["moe_expert_bytes_per_token"] < t16["moe_expert_bytes_per_token"]
    assert t8["bytes_per_token"] < t16["bytes_per_token"]


def _trace_tokens(cfg, params, prompts, lens, arrivals, **ec_kw):
    """Serve one staggered trace; returns ([out_tokens...], engine)."""
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=64,
                              prefill_buckets=(8, 16, 32), **ec_kw),
                 cfg=cfg, params=params)
    reqs = [eng.submit(p[:l], max_new_tokens=6 + 2 * (i % 4),
                       arrival_time=float(a))
            for i, (p, l, a) in enumerate(zip(prompts, lens, arrivals))]
    eng.run()
    assert all(r.t_finished is not None for r in reqs)
    return reqs, eng


@pytest.fixture(scope="module")
def staggered(setup):
    """Staggered Poisson trace over the compressed model (shared across the
    fused/step, gather/ragged, and batched/serial parity tests)."""
    cfg, _, ncfg, nparams, _ = setup
    rng = np.random.default_rng(11)
    lens = [5, 16, 9, 30, 12, 7]
    prompts = [rng.integers(0, cfg.vocab_size, size=32, dtype=np.int32)
               for _ in lens]
    arrivals = poisson_trace(len(lens), rate=0.4, seed=13)
    return ncfg, nparams, prompts, lens, arrivals


def test_fused_block_matches_step_at_a_time(staggered):
    """The tentpole contract: the device-resident K-step decode loop (one
    jitted scan per K tokens, on-device sampling + stop flags) is
    token-for-token identical to the host-driven step-at-a-time loop on a
    staggered Poisson trace — and makes >= 3x fewer host dispatches per
    generated token."""
    ncfg, nparams, prompts, lens, arrivals = staggered
    ref_reqs, e1 = _trace_tokens(ncfg, nparams, prompts, lens, arrivals,
                                 decode_block=1)
    ref_toks = [r.out_tokens for r in ref_reqs]
    for K, min_ratio in ((4, 2.0), (8, 3.0)):
        reqs, eK = _trace_tokens(ncfg, nparams, prompts, lens, arrivals,
                                 decode_block=K)
        toks = [r.out_tokens for r in reqs]
        assert toks == ref_toks, f"fused K={K} diverged from step-at-a-time"
        ratio = (e1.host_dispatches_per_token
                 / eK.host_dispatches_per_token)
        assert ratio >= min_ratio, (
            f"K={K}: only {ratio:.2f}x fewer host dispatches/token "
            f"({eK.host_dispatches_per_token:.3f} vs "
            f"{e1.host_dispatches_per_token:.3f})")


def test_gather_engine_matches_ragged_engine(staggered):
    """dispatch='gather' (decode through the per-token gather kernel) ==
    dispatch='ragged' (grouped kernel everywhere), token for token, on the
    hetero-compressed trace."""
    ncfg, nparams, prompts, lens, arrivals = staggered
    g, _ = _trace_tokens(ncfg, nparams, prompts, lens, arrivals,
                         dispatch="gather")
    r, _ = _trace_tokens(ncfg, nparams, prompts, lens, arrivals,
                         dispatch="ragged")
    assert [q.out_tokens for q in g] == [q.out_tokens for q in r]


def test_batched_admission_matches_serial(staggered):
    """Same-bucket group prefill (one padded batch + one fused
    admit/insert) == the batch-of-1 admission loop: identical tokens AND
    identical admission/finish step accounting."""
    ncfg, nparams, prompts, lens, arrivals = staggered
    # all arrivals at 0 so admissions actually coalesce into groups
    zeros = np.zeros(len(lens))
    b, eb = _trace_tokens(ncfg, nparams, prompts, lens, zeros,
                          batch_admission=True)
    s, es = _trace_tokens(ncfg, nparams, prompts, lens, zeros,
                          batch_admission=False)
    for rb, rs in zip(b, s):
        assert rb.out_tokens == rs.out_tokens
        assert rb.t_admitted == rs.t_admitted
        assert rb.t_finished == rs.t_finished
    assert eb.steps == es.steps


def test_engine_counters_track_tokens():
    """Telemetry sanity: tokens_out equals the tokens actually returned."""
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=32,
                              prefill_buckets=(8,)))
    reqs = [eng.submit(np.ones(8, np.int32), max_new_tokens=5)
            for _ in range(3)]
    eng.run()
    assert eng.counters["tokens_out"] == sum(len(r.out_tokens) for r in reqs)
    assert eng.counters["device_calls"] > 0
    assert eng.host_dispatches_per_token > 0


def test_no_retraces_or_implicit_transfers_after_warmup():
    """DESIGN.md §9 acceptance: over a multi-block decode after warmup the
    trace guard proves the steady state is pure — zero retraces of the
    fused entry point and zero implicit host<->device transfers."""
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=48,
                              prefill_buckets=(8,), decode_block=4))
    # warmup: compile admission + the fused decode block once
    eng.submit(np.ones(8, np.int32), max_new_tokens=4)
    eng.run()
    assert eng._guard.warmed("slot_decode_multi")
    # steady state: several fresh admissions, many fused blocks — all
    # running under transfer_guard("disallow")
    for i in range(3):
        eng.submit(np.arange(1, 9, dtype=np.int32) + i, max_new_tokens=12)
    done = eng.run()
    assert len(done) == 3 and all(len(r.out_tokens) == 12 for r in done)
    assert eng.counters["retraces"] == 0
    assert eng.counters["implicit_transfers"] == 0
    # the fused decode entry point compiled exactly once, ever
    assert eng._guard.traces["slot_decode_multi"] == 1


def test_strict_trace_guard_serves_clean():
    """Strict mode (violations raise) is a no-op on a healthy engine —
    the same guarantee the count-mode counters assert, enforced inline."""
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=32,
                              prefill_buckets=(8,), decode_block=2,
                              trace_guard="strict"))
    for _ in range(2):
        eng.submit(np.ones(8, np.int32), max_new_tokens=6)
    done = eng.run()
    assert len(done) == 2
    assert eng.counters["retraces"] == 0
    assert eng.counters["implicit_transfers"] == 0


def test_admit_pad_shapes_single_source_of_truth(setup):
    """Satellite hardening: every prompt pad length admission may compile
    comes from ONE table (steps.admit_pad_shapes) — bucket_for only ever
    returns members of it, the largest member is exactly s_max, and the
    trace-guard budget is sized from the same table, so scheduler/guard
    shape drift is structurally impossible rather than merely tested."""
    from repro.launch import steps as ST

    shapes = ST.admit_pad_shapes((8, 16), 44)
    # declared buckets + multiples of the biggest one, clamped at s_max
    assert shapes == (8, 16, 32, 44)
    assert shapes[-1] == 44
    cfg, params = setup[0], setup[1]
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=44,
                              prefill_buckets=(8, 16)),
                 cfg=cfg, params=params)
    assert all(eng.bucket_for(n) in shapes for n in range(1, 45))
    # the guard's admission budget counts the SAME table: |shapes| x the
    # power-of-two admission group sizes (1, 2 for n_slots=2)
    assert ST.admit_trace_budget((8, 16), 44, 2) == len(shapes) * 2
    # declared buckets beyond s_max are clamped into the table, never served
    assert ST.admit_pad_shapes((8, 64), 32) == (8, 32)
    clamped = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=32,
                                  prefill_buckets=(8, 64)),
                     cfg=cfg, params=params)
    assert clamped.bucket_for(20) == 32
    with pytest.raises(ValueError, match="no prefill bucket fits"):
        clamped.bucket_for(33)


def test_poisson_trace_deterministic():
    a = poisson_trace(16, rate=0.5, seed=9)
    b = poisson_trace(16, rate=0.5, seed=9)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) > 0).all() and a.shape == (16,)


# --------------------------------------------------------------------------
# request validation + uid uniqueness (DESIGN.md §12 satellites)
# --------------------------------------------------------------------------

def test_submit_rejects_out_of_range_token_ids(setup):
    """Out-of-vocab prompt ids used to clamp silently at the embedding
    gather and serve garbage; now they raise a typed error (which still
    IS a ValueError, so pre-§12 callers keep working)."""
    from repro.core import errors as ERR
    cfg, params = setup[0], setup[1]
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=32,
                              prefill_buckets=(P,)),
                 cfg=cfg, params=params)
    bad_hi = np.array([0, 1, cfg.vocab_size], np.int32)
    bad_lo = np.array([0, -3, 1], np.int32)
    for bad in (bad_hi, bad_lo):
        with pytest.raises(ERR.InvalidTokenError, match="vocab"):
            eng.submit(bad, max_new_tokens=2)
    assert issubclass(ERR.InvalidTokenError, ValueError)
    assert eng.idle                         # rejected before enqueue


def test_duplicate_inflight_uids_alias_gumbel_streams(setup):
    """Why in-flight uids must be unique: the per-slot sampling key is
    fold_in(base, uid), so two live requests with one uid draw the SAME
    Gumbel noise — at temperature > 0 their sampled streams are bitwise
    identical (aliased), which silently corrupts sampled-mode parity.
    This test first DEMONSTRATES the corruption by smuggling a duplicate
    past the guard, then pins the guard that now makes it unreachable."""
    import heapq

    from repro.core import errors as ERR
    from repro.serving import Request
    cfg, params, _, _, prompts = setup
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=32,
                              prefill_buckets=(P,), temperature=1.0),
                 cfg=cfg, params=params)

    # (1) the old behavior, reproduced by bypassing submit(): same uid,
    # same prompt, both slots live at once -> identical sampled streams
    twins = [Request(uid=7, prompt=prompts[0].copy(), max_new_tokens=NEW)
             for _ in range(2)]
    for i, r in enumerate(twins):
        heapq.heappush(eng._pending, (0.0, r.uid, i, r))
    eng.run()
    assert twins[0].out_tokens == twins[1].out_tokens
    assert len(twins[0].out_tokens) == NEW

    # (2) distinct uids, same prompt: fold_in separates the noise streams
    a = eng.submit(prompts[0], max_new_tokens=NEW, uid=8)
    b = eng.submit(prompts[0], max_new_tokens=NEW, uid=9)
    eng.run()
    assert a.out_tokens != b.out_tokens

    # (3) the guard: a duplicate of an IN-FLIGHT uid is rejected at
    # submit, at run(requests=...), and by the internal enqueue
    eng.submit(prompts[1], max_new_tokens=NEW, uid=42)
    with pytest.raises(ERR.DuplicateUidError, match="fold_in"):
        eng.submit(prompts[2], max_new_tokens=NEW, uid=42)
    dup = [Request(uid=5, prompt=prompts[0].copy(), max_new_tokens=2),
           Request(uid=5, prompt=prompts[1].copy(), max_new_tokens=2)]
    with pytest.raises(ERR.DuplicateUidError, match="unique"):
        eng.run(requests=dup)
    done = eng.run()                        # uid 42 still serves cleanly
    assert [r.uid for r in done] == [42]
