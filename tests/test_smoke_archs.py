"""Assignment smoke tests: every architecture instantiates a REDUCED config
of the same family and runs one forward + one train step on CPU, asserting
output shapes and finiteness. Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as MD
from repro.launch import steps as ST
from repro.optim import make_optimizer

B, S = 2, 32


def _batch(cfg, key=0):
    rng = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones(
            (B, cfg.vlm_num_patches, cfg.d_model), cfg.param_dtype)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.n_audio_ctx, cfg.d_model)).astype(cfg.param_dtype)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get(arch).reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    logits, aux, _ = MD.forward(cfg, params, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.slow
@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_one_train_step(arch):
    cfg = configs.get(arch).reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer("adamw", lr=1e-3)
    opt_state = opt.init(params)
    step = ST.make_train_step(cfg, opt)
    p2, o2, loss, metrics = jax.jit(step)(params, opt_state, _batch(cfg),
                                          jnp.asarray(0, jnp.int32))
    assert np.isfinite(float(loss))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, p2)
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL config must carry the exact assignment table values."""
    cfg = configs.get(arch)
    expected = {
        "yi_34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen1_5_110b": (80, 8192, 64, 8, 49152, 152064),
        "granite_8b": (36, 4096, 32, 8, 14336, 49152),
        "phi3_medium_14b": (40, 5120, 40, 10, 17920, 100352),
        "kimi_k2_1t_a32b": (61, 7168, 64, 8, 0, 163840),
        "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 0, 151936),
        "phi_3_vision_4_2b": (32, 3072, 32, 32, 8192, 32064),
        "zamba2_2_7b": (54, 2560, 32, 32, 10240, 32000),
        "mamba2_370m": (48, 1024, 16, 16, 0, 50280),
        "whisper_base": (6, 512, 8, 8, 2048, 51865),
    }[configs.canonical(arch)]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_moe_configs_match_assignment():
    k = configs.get("kimi-k2-1t-a32b")
    assert (k.moe.n_experts, k.moe.top_k, k.moe.d_ff_expert) == (384, 8, 2048)
    q = configs.get("qwen3-moe-30b-a3b")
    assert (q.moe.n_experts, q.moe.top_k, q.moe.d_ff_expert) == (128, 8, 768)
    z = configs.get("zamba2-2.7b")
    assert z.ssm.d_state == 64
    m = configs.get("mamba2-370m")
    assert m.ssm.d_state == 128


def test_param_counts_plausible():
    """Param accounting lands in the advertised size class."""
    expect = {
        "yi-34b": 34e9, "qwen1.5-110b": 110e9, "granite-8b": 8e9,
        "phi3-medium-14b": 14e9, "kimi-k2-1t-a32b": 1.0e12,
        "qwen3-moe-30b-a3b": 30e9, "phi-3-vision-4.2b": 4.2e9,
        "zamba2-2.7b": 2.7e9, "mamba2-370m": 0.37e9,
    }
    for arch, n in expect.items():
        got = configs.get(arch).param_count()
        assert 0.6 * n < got < 1.45 * n, (arch, got, n)


def test_long_context_applicability():
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        ok = configs.shape_applicable(cfg, "long_500k")
        assert ok == (cfg.family in ("ssm", "hybrid"))
