"""Continuous-batching engine: decode parity against the fixed-batch loop
(greedy, same seed — token-for-token), compressed and uncompressed, plus
scheduler semantics (admission order, slot reuse isolation, stop conditions).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import compress as CMP
from repro.launch.serve import FixedBatchServer, ServeConfig
from repro.models import model as MD
from repro.serving import Engine, EngineConfig, poisson_trace

ARCH = "qwen3-moe-30b-a3b"
B, P, NEW = 4, 16, 8


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get(ARCH).reduced()
    # serving path: ragged dispatch for BOTH loops so parity is exact
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="ragged"))
    params = MD.init(cfg, jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    ncfg, nparams, _ = CMP.compress_model(
        cfg, params, method="mergemoe",
        merged_experts=cfg.moe.n_experts // 2, split=0, batches=calib)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(B, P), dtype=np.int32)
    return cfg, params, ncfg, nparams, prompts


def _engine_out(cfg, params, prompts, n_slots=B, s_max=P + NEW + 4):
    eng = Engine(EngineConfig(arch=ARCH, n_slots=n_slots, s_max=s_max,
                              prefill_buckets=(P,)),
                 cfg=cfg, params=params)
    for i in range(prompts.shape[0]):
        eng.submit(prompts[i], max_new_tokens=NEW)
    done = eng.run()
    assert [r.uid for r in done] == list(range(prompts.shape[0]))
    return np.stack([np.asarray(r.out_tokens) for r in done])


@pytest.mark.parametrize("compressed", [False, True],
                         ids=["uncompressed", "mergemoe-m-half"])
def test_decode_parity_engine_vs_fixed_batch(setup, compressed):
    """Greedy continuous-batching output == fixed-batch output,
    token for token, through the ragged/grouped-kernel MoE path."""
    cfg, params, ncfg, nparams, prompts = setup
    c, p = (ncfg, nparams) if compressed else (cfg, params)
    fixed = FixedBatchServer(
        ServeConfig(arch=ARCH, batch_size=B, prompt_len=P,
                    max_new_tokens=NEW), cfg=c, params=p)
    ref = fixed.generate(prompts)
    out = _engine_out(c, p, prompts)
    np.testing.assert_array_equal(out, ref)


def test_slot_turnover_isolation(setup):
    """A request decoded in a busy 2-slot engine (slots recycled across
    requests, mixed prompt lengths) must be token-identical to the same
    request served alone — stale KV from evicted occupants never leaks."""
    cfg, params, ncfg, nparams, _ = setup
    rng = np.random.default_rng(3)
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=64,
                              prefill_buckets=(8, 16, 32)),
                 cfg=ncfg, params=nparams)
    reqs = []
    for i, (ln, arr) in enumerate(zip([5, 16, 9, 30, 12], [0, 0, 1, 2, 2])):
        reqs.append(eng.submit(
            rng.integers(0, cfg.vocab_size, size=ln, dtype=np.int32),
            max_new_tokens=4 + i, arrival_time=float(arr)))
    done = eng.run()
    assert len(done) == len(reqs)
    for r in done:
        solo = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=64,
                                   prefill_buckets=(8, 16, 32)),
                      cfg=ncfg, params=nparams)
        sr = solo.submit(r.prompt, max_new_tokens=r.max_new_tokens)
        solo.run()
        assert sr.out_tokens == r.out_tokens


def test_parity_split_stack(setup):
    """Compression with split > 0 leaves a prefix of uncompressed layers
    ('stack') ahead of the merged suffix ('stack_c'); the engine's prefill
    and decode must thread the slot cache through both."""
    cfg, params, _, _, prompts = setup
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(8), (4, 64),
                                           0, cfg.vocab_size)}]
    scfg, sparams, _ = CMP.compress_model(
        cfg, params, method="mergemoe",
        merged_experts=cfg.moe.n_experts // 2, split=1, batches=calib)
    assert "stack" in sparams and "stack_c" in sparams
    fixed = FixedBatchServer(
        ServeConfig(arch=ARCH, batch_size=B, prompt_len=P,
                    max_new_tokens=NEW), cfg=scfg, params=sparams)
    ref = fixed.generate(prompts)
    out = _engine_out(scfg, sparams, prompts)
    np.testing.assert_array_equal(out, ref)


def test_admission_respects_arrival_and_capacity(setup):
    cfg, params, _, _, _ = setup
    rng = np.random.default_rng(1)
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=48,
                              prefill_buckets=(8,)), cfg=cfg, params=params)
    # submitted OUT of arrival order: the queue must re-sort, so an early
    # submission with a late arrival never blocks a later-due request
    for i in (2, 0, 3, 1):
        eng.submit(rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32),
                   max_new_tokens=3, arrival_time=float(i), uid=i)
    done = eng.run()
    admitted = [r.t_admitted for r in done]      # done sorted by uid==arrival
    assert admitted == sorted(admitted)
    # FIFO by arrival; a request is never admitted before it arrives, and
    # with 2 slots the last two must wait for evictions. An admission step
    # yields two tokens (prefill logits + the same step's decode), so a
    # request occupies its slot for max_new_tokens - 2 further steps.
    for r in done:
        assert r.t_admitted >= r.arrival_time
        assert r.t_finished - r.t_admitted == max(0, r.max_new_tokens - 2)
    assert done[2].t_admitted >= done[0].t_finished


def test_stop_conditions(setup):
    """max_new_tokens == 1 finishes at admission; eos_token stops early."""
    cfg, params, _, _, _ = setup
    rng = np.random.default_rng(2)
    eng = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=48,
                              prefill_buckets=(8,)), cfg=cfg, params=params)
    prompt = rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32)
    one = eng.submit(prompt, max_new_tokens=1)
    eng.run()
    assert len(one.out_tokens) == 1 and one.finish_reason == "length"

    # find what greedy decodes first, then use it as the eos token
    probe = eng.submit(prompt, max_new_tokens=4)
    eng.run()
    eos = probe.out_tokens[1]
    stopped = eng.submit(prompt, max_new_tokens=10, eos_token=eos)
    eng.run()
    assert stopped.finish_reason == "eos"
    assert stopped.out_tokens == probe.out_tokens[:2]


def test_bucket_never_exceeds_slot_capacity(setup):
    """A prompt whose bucket rounds past s_max must still serve: the pad
    length is clamped to the slot size (regression — previously crashed in
    insert_slot's dynamic_update_slice)."""
    cfg, params, _, _, _ = setup
    eng = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=44,
                              prefill_buckets=(8, 16)), cfg=cfg, params=params)
    assert eng.bucket_for(40) == 44
    req = eng.submit(np.ones(40, np.int32), max_new_tokens=4)
    eng.run()
    assert len(req.out_tokens) == 4


def test_submit_validation(setup):
    cfg, params, _, _, _ = setup
    eng = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=16,
                              prefill_buckets=(8,)), cfg=cfg, params=params)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(12, np.int32), max_new_tokens=8)  # > s_max
    with pytest.raises(ValueError):
        eng.submit(np.zeros(0, np.int32), max_new_tokens=1)


def test_poisson_trace_deterministic():
    a = poisson_trace(16, rate=0.5, seed=9)
    b = poisson_trace(16, rate=0.5, seed=9)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) > 0).all() and a.shape == (16,)
