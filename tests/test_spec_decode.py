"""Self-speculative decoding (DESIGN.md §10): the MergeMoE-compressed model
drafts K tokens per slot, the full model verifies them in one multi-position
forward, and accept/rollback happens on device.

The contract under test is EXACTNESS, not similarity: whatever the draft
proposes, the committed tokens must be bitwise what the full model would
have produced — greedy via longest-matching-prefix + verify-sample commit,
and at temperature > 0 via the position-indexed Gumbel key schedule (the
noise for the token occupying sequence position q of request uid depends
only on (seed, uid, q), never on engine mode), so draft and verify score
each position under the SAME noise and acceptance is exact coupling.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import compress as CMP
from repro.launch import steps as ST
from repro.models import model as MD
from repro.serving import Engine, EngineConfig, accept_drafts, poisson_trace

ARCH = "qwen3-moe-30b-a3b"


# --------------------------------------------------------------------------
# sampling primitive: position-indexed Gumbel (satellite: temperature > 0)
# --------------------------------------------------------------------------

def test_sample_tokens_greedy_is_argmax():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(3, 17)),
                         jnp.float32)
    keys = jnp.zeros((3, 2), jnp.uint32)
    pos = jnp.arange(3, dtype=jnp.int32)
    out = ST.sample_tokens(logits, 0.0, keys, pos)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.argmax(np.asarray(logits), -1))


def test_sample_tokens_device_matches_host():
    """The jitted (device) sampler and the eager (host) sampler are the one
    function evaluated two ways — bitwise-identical tokens. This is what
    lets the engine's host-side admission sampling agree with the on-device
    decode/verify sampling."""
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(5, 64)), jnp.float32)
    base = jax.random.PRNGKey(123)
    keys = jnp.stack([jax.random.fold_in(base, u) for u in range(5)])
    pos = jnp.asarray([0, 7, 7, 31, 2], jnp.int32)
    jitted = jax.jit(lambda l, k, p: ST.sample_tokens(l, 0.7, k, p))
    host = ST.sample_tokens(logits, 0.7, keys, pos)
    np.testing.assert_array_equal(np.asarray(jitted(logits, keys, pos)),
                                  np.asarray(host))


def test_sample_tokens_keyed_by_position_only():
    """Noise depends only on (key, position) — not on where the row sits in
    the batch. This is the property that makes draft-step j and
    verify-position j score the same token under the same noise."""
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(2, 32)), jnp.float32)
    k1 = jax.random.fold_in(jax.random.PRNGKey(9), 4)
    k2 = jax.random.fold_in(jax.random.PRNGKey(9), 5)
    batched = ST.sample_tokens(logits, 0.5, jnp.stack([k1, k2]),
                               jnp.asarray([11, 3], jnp.int32))
    solo0 = ST.sample_tokens(logits[:1], 0.5, k1[None],
                             jnp.asarray([11], jnp.int32))
    solo1 = ST.sample_tokens(logits[1:], 0.5, k2[None],
                             jnp.asarray([3], jnp.int32))
    assert int(batched[0]) == int(solo0[0])
    assert int(batched[1]) == int(solo1[0])
    # different position => different noise => (almost surely) some
    # different draws across a sweep
    sweep = [int(ST.sample_tokens(logits[:1], 1.5, k1[None],
                                  jnp.asarray([q], jnp.int32))[0])
             for q in range(32)]
    assert len(set(sweep)) > 1


def test_sample_tokens_distribution_matches_softmax():
    """Gumbel-max sampling is distributionally exact: over many positions
    (independent keys) the empirical token frequencies converge to
    softmax(logits / T)."""
    V, N, T = 8, 8192, 1.0
    logits = jnp.linspace(0.0, 2.0, V, dtype=jnp.float32)
    key = jax.random.PRNGKey(77)
    toks = ST.sample_tokens(jnp.broadcast_to(logits, (N, V)), T,
                            jnp.broadcast_to(key, (N, 2)),
                            jnp.arange(N, dtype=jnp.int32))
    freq = np.bincount(np.asarray(toks), minlength=V) / N
    expect = np.asarray(jax.nn.softmax(logits / T))
    np.testing.assert_allclose(freq, expect, atol=4.0 / np.sqrt(N))


# --------------------------------------------------------------------------
# acceptance rule unit tests (satellite: rollback edge cases)
# --------------------------------------------------------------------------

def _accept(drafts, verify, active=True, remaining=99, eos=-1, k=4):
    out = accept_drafts(
        jnp.asarray([drafts], jnp.int32), jnp.asarray([verify], jnp.int32),
        jnp.asarray([active]), jnp.asarray([remaining], jnp.int32),
        jnp.asarray([eos], jnp.int32), k)
    emitted, n_commit, n_match, still = (np.asarray(x)[0] for x in out)
    return emitted, int(n_commit), int(n_match), bool(still)


def test_accept_all_rejected_still_commits_one():
    """Every draft wrong: the round still commits v_0 (the verify sample at
    the round's first position) — progress is guaranteed, never a stall."""
    emitted, n_commit, n_match, still = _accept([1, 2, 3, 4], [9, 8, 7, 6, 5])
    assert n_match == 0 and n_commit == 1
    np.testing.assert_array_equal(emitted, [True, False, False, False])
    assert still


def test_accept_all_accepted_commits_k_without_bonus():
    """Every draft right: commit exactly K — the classic bonus (K+1)th
    verify token is deliberately NOT committed, because the draft cache has
    no KV row for it and committing it would leave an attended hole."""
    emitted, n_commit, n_match, still = _accept([1, 2, 3, 4], [1, 2, 3, 4, 9])
    assert n_match == 4 and n_commit == 4
    np.testing.assert_array_equal(emitted, [True] * 4)
    assert still


def test_accept_partial_prefix():
    """First divergence cuts the prefix; the verify sample at the cut
    position is committed in the rejected draft's place."""
    emitted, n_commit, n_match, _ = _accept([1, 2, 3, 4], [1, 2, 9, 9, 9])
    assert n_match == 2 and n_commit == 3
    np.testing.assert_array_equal(emitted, [True, True, True, False])


def test_accept_eos_inside_accepted_prefix_stops_slot():
    emitted, n_commit, n_match, still = _accept(
        [1, 2, 3, 4], [1, 2, 3, 4, 9], eos=2)
    assert n_match == 4
    assert n_commit == 2                      # tokens after the eos dropped
    np.testing.assert_array_equal(emitted, [True, True, False, False])
    assert not still                          # slot froze on eos


def test_accept_remaining_budget_truncates():
    emitted, n_commit, _, still = _accept([1, 2, 3, 4], [1, 2, 3, 4, 9],
                                          remaining=2)
    assert n_commit == 2
    np.testing.assert_array_equal(emitted, [True, True, False, False])
    assert not still                          # budget exhausted


def test_accept_inactive_slot_commits_nothing():
    emitted, n_commit, n_match, still = _accept([1, 2, 3, 4], [1, 2, 3, 4, 9],
                                                active=False)
    assert n_commit == 0 and n_match == 0 and not still
    np.testing.assert_array_equal(emitted, [False] * 4)


def test_accept_eos_negative_means_disabled():
    """eos == -1 (no eos token) must never match, even against token 0 or
    negative-looking garbage."""
    emitted, n_commit, _, still = _accept([0, 0, 0, 0], [0, 0, 0, 0, 0],
                                          eos=-1)
    assert n_commit == 4 and still


# --------------------------------------------------------------------------
# engine-level parity on a staggered Poisson trace
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = configs.get(ARCH).reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    ncfg, nparams, _ = CMP.compress_model(
        cfg, params, method="mergemoe",
        merged_experts=cfg.moe.n_experts // 2, split=0, batches=calib)
    # adversarial draft: an unrelated random init at the same shape — worst
    # case for acceptance, identical contract for exactness
    adv = MD.init(cfg, jax.random.PRNGKey(5))
    rng = np.random.default_rng(11)
    lens = [5, 16, 9, 30, 12, 7]
    prompts = [rng.integers(0, cfg.vocab_size, size=l, dtype=np.int32)
               for l in lens]
    arrivals = poisson_trace(len(lens), rate=0.4, seed=13)
    return cfg, params, ncfg, nparams, adv, prompts, arrivals


def _serve(cfg, params, prompts, arrivals, *, temperature=0.0,
           draft=None, spec_k=3, **ec_kw):
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=64,
                              prefill_buckets=(8, 16, 32),
                              temperature=temperature, spec_k=spec_k,
                              **ec_kw),
                 cfg=cfg, params=params,
                 draft_cfg=draft[0] if draft else None,
                 draft_params=draft[1] if draft else None)
    for i, (p, a) in enumerate(zip(prompts, arrivals)):
        eng.submit(p, max_new_tokens=6 + 2 * (i % 4),
                   arrival_time=float(a), uid=i)
    done = eng.run()
    return {r.uid: list(r.out_tokens) for r in done}, eng


@pytest.fixture(scope="module")
def refs(setup):
    """Plain full-model engine outputs on the trace — the ground truth every
    spec configuration must match bitwise — at both test temperatures."""
    cfg, params, _, _, _, prompts, arrivals = setup
    return {t: _serve(cfg, params, prompts, arrivals, temperature=t)[0]
            for t in (0.0, 0.7)}


@pytest.fixture(scope="module")
def merged_spec(setup):
    """One greedy spec run with the MergeMoE M=N/2 draft, shared by the
    parity / rollback / lockstep assertions."""
    cfg, params, ncfg, nparams, _, prompts, arrivals = setup
    return _serve(cfg, params, prompts, arrivals, draft=(ncfg, nparams))


def test_spec_engine_matches_full_engine_greedy(setup, refs, merged_spec):
    """The tentpole contract: the speculative engine (compressed draft +
    full verify + on-device accept/rollback) is token-for-token identical
    to the plain full-model engine on a staggered Poisson trace."""
    out, eng = merged_spec
    assert out == refs[0.0]
    # the draft actually drafted (the parity above wasn't vacuous)
    assert eng.counters["tokens_drafted"] > 0
    assert 0.0 <= eng.acceptance_rate <= 1.0


def test_spec_engine_matches_full_engine_sampled(setup, refs):
    """Same contract at temperature 0.7, where exactness rides entirely on
    the position-indexed Gumbel coupling between draft and verify."""
    cfg, params, ncfg, nparams, _, prompts, arrivals = setup
    out, eng = _serve(cfg, params, prompts, arrivals, temperature=0.7,
                      draft=(ncfg, nparams))
    assert out == refs[0.7]
    assert eng.counters["tokens_drafted"] > 0


def test_adversarial_draft_still_exact(setup, refs):
    """Exactness must not depend on the draft being any good: an unrelated
    random model drafts, almost everything is rejected and rolled back,
    and the output is STILL bitwise the full model's."""
    cfg, params, _, _, adv, prompts, arrivals = setup
    out, eng = _serve(cfg, params, prompts, arrivals, draft=(cfg, adv))
    assert out == refs[0.0]
    # near-chance acceptance, heavy rollback traffic
    assert eng.acceptance_rate < 0.25
    assert eng.counters["tokens_rolled_back"] > 0


def test_self_draft_accepts_everything(setup):
    """Draft == verify weights: every proposal must be accepted (the sharp
    end-to-end check that draft decode and multi-position verify agree
    bitwise position by position)."""
    cfg, params, _, _, _, prompts, arrivals = setup
    _, eng = _serve(cfg, params, prompts, arrivals, draft=(cfg, params))
    assert eng.counters["tokens_drafted"] > 0
    assert eng.acceptance_rate == 1.0
    assert eng.counters["tokens_rolled_back"] == 0


def test_caches_stay_in_lockstep_after_rollbacks(merged_spec):
    """After a trace full of partial rollbacks on staggered arrivals, the
    full and draft KV caches must agree on every slot's position — the
    free-rollback scheme (pos = pos0 + n_commit, stale rows masked) never
    lets them drift."""
    _, eng = merged_spec
    assert eng.counters["tokens_rolled_back"] > 0    # rollbacks did happen
    np.testing.assert_array_equal(np.asarray(eng.cache["pos"]),
                                  np.asarray(eng.cache_draft["pos"]))


def test_spec_trace_guard_no_retraces(setup):
    """The whole draft->verify->accept round is ONE jitted program: it
    compiles exactly once and the steady state makes no implicit
    host<->device transfers (DESIGN.md §9 discipline extended to §10)."""
    cfg, params, ncfg, nparams, _, _, _ = setup
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=48,
                              prefill_buckets=(8,), spec_k=3),
                 cfg=cfg, params=params, draft_cfg=ncfg, draft_params=nparams)
    eng.submit(np.ones(8, np.int32), max_new_tokens=4)
    eng.run()
    assert eng._guard.warmed("slot_decode_spec")
    for i in range(3):
        eng.submit(np.arange(1, 9, dtype=np.int32) + i, max_new_tokens=12)
    done = eng.run()
    assert len(done) == 3 and all(len(r.out_tokens) == 12 for r in done)
    assert eng.counters["retraces"] == 0
    assert eng.counters["implicit_transfers"] == 0
    assert eng._guard.traces["slot_decode_spec"] == 1


def test_spec_config_validation(setup):
    cfg, params, ncfg, nparams, _, _, _ = setup
    with pytest.raises(ValueError):
        Engine(EngineConfig(arch=ARCH, spec_k=0),
               cfg=cfg, params=params, draft_cfg=ncfg, draft_params=nparams)
    with pytest.raises(ValueError):          # draft params without a config
        Engine(EngineConfig(arch=ARCH),
               cfg=cfg, params=params, draft_params=nparams)


def test_spec_submit_requires_verify_headroom(setup):
    """Speculative verify writes up to spec_k lookahead KV rows past the
    committed stream, so a spec engine must reject
    prompt + max_new + spec_k > s_max + 1 at SUBMISSION (regression: the
    pre-fix engine accepted these, and the last verify rounds of a
    capacity-filling request scattered past s_max — clipped into the last
    row dense, dropped at the sentinel paged — silently corrupting the KV
    its own acceptance then read). The same request is fine without spec."""
    cfg, params, ncfg, nparams, _, _, _ = setup
    eng = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=32,
                              prefill_buckets=(16,), spec_k=3),
                 cfg=cfg, params=params, draft_cfg=ncfg, draft_params=nparams)
    bound = 32 + 1 - 3 - 16              # largest spec-servable max_new
    eng.submit(np.ones(16, np.int32), max_new_tokens=bound)
    with pytest.raises(ValueError, match="lookahead headroom"):
        eng.submit(np.ones(16, np.int32), max_new_tokens=bound + 1)
    done = eng.run()                     # the in-bounds request serves fully
    assert len(done) == 1 and len(done[0].out_tokens) == bound
    # a NON-spec engine accepts the longer request: the headroom rule is
    # gated on spec mode, not folded into the base capacity bound
    plain = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=32,
                                prefill_buckets=(16,)),
                   cfg=cfg, params=params)
    plain.submit(np.ones(16, np.int32), max_new_tokens=bound + 1)


def test_spec_exact_at_capacity_boundary(setup):
    """Adversarial boundary: a spec request sized EXACTLY to the headroom
    bound drives verify rounds whose lookahead writes reach the last
    reserved rows (pos0 + K lands at s_max). Committed tokens must still be
    bitwise the plain full-model engine's — the reserved headroom absorbs
    every lookahead write, so nothing the acceptance reads was clipped or
    dropped. Self-draft maximizes pressure: all-accept rounds advance K at
    a time right up to the end of the slot."""
    cfg, params, ncfg, nparams, _, _, _ = setup
    bound = 32 + 1 - 3 - 16
    prompt = np.arange(1, 17, dtype=np.int32)
    ref_eng = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=32,
                                  prefill_buckets=(16,)),
                     cfg=cfg, params=params)
    ref = ref_eng.submit(prompt, max_new_tokens=bound)
    ref_eng.run()
    for draft in ((cfg, params), (ncfg, nparams)):
        eng = Engine(EngineConfig(arch=ARCH, n_slots=1, s_max=32,
                                  prefill_buckets=(16,), spec_k=3),
                     cfg=cfg, params=params, draft_cfg=draft[0],
                     draft_params=draft[1])
        req = eng.submit(prompt, max_new_tokens=bound)
        eng.run()
        assert req.out_tokens == ref.out_tokens


# --------------------------------------------------------------------------
# seeded sampling through the engine (satellite: bench_decode seed fix)
# --------------------------------------------------------------------------

def test_engine_sampling_threads_config_seed(setup):
    """EngineConfig.seed drives every sampling key (admission, decode,
    spec): same seed -> bitwise-identical sampled outputs, different seed
    -> different draws. Regression for bench/decode paths hardcoding
    PRNGKey(0)."""
    cfg, params, _, _, _, prompts, arrivals = setup
    a, _ = _serve(cfg, params, prompts, arrivals, temperature=0.7, seed=3)
    b, _ = _serve(cfg, params, prompts, arrivals, temperature=0.7, seed=3)
    c, _ = _serve(cfg, params, prompts, arrivals, temperature=0.7, seed=4)
    assert a == b
    assert a != c


def test_bench_decode_runs_seeded_at_temperature(setup):
    """Engine.bench_decode samples with keys derived from EngineConfig.seed
    (not a hardcoded PRNGKey(0)) and runs transfer-clean at temperature>0;
    bench_spec_decode does the same for the speculative round."""
    cfg, params, ncfg, nparams, _, _, _ = setup
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=48,
                              prefill_buckets=(8,), temperature=0.7, seed=5),
                 cfg=cfg, params=params)
    out = eng.bench_decode(iters=2)
    assert out["tok_per_s"] > 0
    spec = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=48,
                               prefill_buckets=(8,), temperature=0.7,
                               seed=5, spec_k=2),
                  cfg=cfg, params=params, draft_cfg=ncfg,
                  draft_params=nparams)
    b = spec.bench_spec_decode(iters=2)
    assert b["tok_per_s"] > 0
    assert 0.0 <= b["acceptance_rate"] <= 1.0
    assert b["k_draft"] == 2
    assert b["host_dispatches_per_token"] > 0
