"""Retrace + implicit-transfer guard for jitted serving entry points.

DESIGN.md §7's dispatches-per-token win rests on two runtime properties the
type system cannot see: each jitted entry point compiles a BOUNDED number
of specializations (decode: exactly one; admission: one per
(bucket, pow2-group) pair), and the steady-state decode block moves NO data
between host and device except the one ``[K, B]`` token readback the engine
counts in ``host_syncs``. Both regress silently — a stray ``float(...)`` on
config, a numpy arg that slips past ``jnp.asarray``, a shape that varies
per call — and only show up as a 10x wall-clock cliff on real hardware.

:class:`TraceGuard` makes both properties observable and enforceable:

* ``wrap_jit(name, fn, expected_traces)`` jits ``fn`` with a shim that
  counts Python-body executions — i.e. actual traces, not dispatches.
  Traces beyond ``expected_traces`` increment ``counters["retraces"]``
  (mode ``"count"``) or raise :class:`TraceGuardError` (mode ``"strict"``).
* ``run(name, fn, *args)`` executes one call. Once ``name`` has traced at
  least once (warmup done — compilation itself legitimately transfers
  constants), the call runs under ``jax.transfer_guard("disallow")``: any
  implicit device<->host transfer increments
  ``counters["implicit_transfers"]`` (count mode re-executes unguarded —
  jitted calls are pure, so the retry is side-effect-free) or raises
  (strict mode).

Mode ``"off"`` degrades to plain ``jax.jit`` with zero overhead besides
the trace counter.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax

__all__ = ["TraceGuard", "TraceGuardError"]


class TraceGuardError(RuntimeError):
    """A guarded invariant (no retraces / no implicit transfers) broke in
    strict mode."""


class TraceGuard:
    """Per-engine registry of guarded jitted callables.

    ``counters`` may be a shared dict (the Engine passes its own
    ``counters``); the guard only touches the ``"retraces"`` and
    ``"implicit_transfers"`` keys, creating them if absent.
    """

    def __init__(self, mode: str = "count",
                 counters: Optional[Dict[str, int]] = None):
        if mode not in ("off", "count", "strict"):
            raise ValueError(f"unknown trace-guard mode {mode!r}")
        self.mode = mode
        self.counters: Dict[str, int] = (
            counters if counters is not None else {})
        self.counters.setdefault("retraces", 0)
        self.counters.setdefault("implicit_transfers", 0)
        self.traces: Dict[str, int] = {}
        self.expected: Dict[str, int] = {}

    # ------------------------------------------------------------- wrapping
    def wrap_jit(self, name: str, fn: Callable, expected_traces: int = 1,
                 **jit_kwargs: Any) -> Callable:
        """``jax.jit(fn)`` with trace counting under ``name``.

        The counting shim runs inside the traced body, so it executes once
        per compilation, never per dispatch — steady-state overhead is
        zero. ``expected_traces`` is the specialization budget; traces
        beyond it are retraces."""
        self.traces.setdefault(name, 0)
        self.expected[name] = int(expected_traces)

        def counted(*args, **kw):
            self.traces[name] += 1
            if self.traces[name] > self.expected[name]:
                self.counters["retraces"] += 1
                if self.mode == "strict":
                    raise TraceGuardError(
                        f"`{name}` traced {self.traces[name]} times "
                        f"(budget {self.expected[name]}): an argument's "
                        f"shape/dtype or a closed-over static changed "
                        f"after warmup")
            return fn(*args, **kw)

        counted.__name__ = getattr(fn, "__name__", name)
        return jax.jit(counted, **jit_kwargs)

    # ------------------------------------------------------------- running
    def warmed(self, name: str) -> bool:
        """True once ``name`` has compiled at least once (transfer guard
        arms only past this point — compilation itself device_puts
        constants, which is legitimate)."""
        return self.traces.get(name, 0) >= 1

    def run(self, name: str, fn: Callable, *args: Any) -> Any:
        """Execute ``fn(*args)``; steady-state calls run under
        ``jax.transfer_guard("disallow")``."""
        if self.mode == "off" or not self.warmed(name):
            return fn(*args)
        try:
            with jax.transfer_guard("disallow"):
                return fn(*args)
        except Exception as e:  # transfer-guard violations are plain errors
            if "transfer" not in str(e).lower():
                raise
            self.counters["implicit_transfers"] += 1
            if self.mode == "strict":
                raise TraceGuardError(
                    f"implicit device<->host transfer inside guarded "
                    f"`{name}`: {e}") from e
            return fn(*args)
