"""Precision helpers.

TPU target semantics: bf16 operands, fp32 accumulation via
``preferred_element_type``. This container's CPU XLA build cannot *execute*
``BF16 x BF16 = F32`` dots, so when real values flow on CPU we upcast the
operands instead (numerically identical or better; CPU perf is irrelevant).

``REPRO_TPU_SEMANTICS=1`` forces the TPU form — used by the dry-run, which
only lowers/compiles and never executes, so the roofline byte counts reflect
the real bf16 program rather than fp32-upcast copies.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

F32 = jnp.float32


def tpu_semantics() -> bool:
    if os.environ.get("REPRO_TPU_SEMANTICS") == "1":
        return True
    return jax.default_backend() == "tpu"


def ein(eq: str, *args):
    """Projection einsum. TPU: bf16 operands/outputs (the MXU accumulates
    fp32 internally; bf16 output buffers keep activation-cotangent collectives
    and HBM traffic at half size). CPU execution: fp32 upcast (this backend
    cannot execute bf16 dots), result cast back to the operands' dtype."""
    if tpu_semantics():
        return jnp.einsum(eq, *args)
    out_dtype = jnp.result_type(*args)
    out = jnp.einsum(eq, *[a.astype(F32) for a in args])
    return out.astype(out_dtype)


@jax.custom_vjp
def bf16_cotangent(x):
    """Identity whose BACKWARD casts the cotangent to bf16.

    Placed after the LM head and at sharding boundaries so fp32 loss-side
    gradients don't propagate fp32 activation cotangents through the whole
    stack (halves backward collective + HBM traffic; standard
    mixed-precision training practice)."""
    return x


def _bf16_ct_fwd(x):
    return x, None


def _bf16_ct_bwd(_, g):
    # primal is always bf16 where this barrier is applied; the incoming
    # cotangent may have been promoted to f32 upstream.
    return (g.astype(jnp.bfloat16),)


bf16_cotangent.defvjp(_bf16_ct_fwd, _bf16_ct_bwd)


def ein32(eq: str, *args):
    """einsum with an fp32 OUTPUT buffer — for precision-critical results
    (attention logits pre-softmax, router logits, LM-head logits)."""
    if tpu_semantics():
        return jnp.einsum(eq, *args, preferred_element_type=F32)
    return jnp.einsum(eq, *[a.astype(F32) for a in args])


def dot(a, b):
    if tpu_semantics():
        return jnp.dot(a, b, preferred_element_type=F32)
    return jnp.dot(a.astype(F32), b.astype(F32))


# ---------------------------------------------------------------------------
# activation sharding constraints
# ---------------------------------------------------------------------------
# The launcher installs the active mesh here; model code then pins activation
# shardings at block boundaries via ``constrain`` so GSPMD never floats a
# batch-replicated intermediate (e.g. the embedding one-hot matmul).
_ACT = {"mesh": None, "dp": None, "m": "model"}


def set_activation_mesh(mesh, dp=None, m="model") -> None:
    """dp: explicit batch axes tuple (default: pod+data). m: the tensor-
    parallel axis name, or None for pure-DP profiles (small models)."""
    _ACT["mesh"] = mesh
    _ACT["dp"] = dp
    _ACT["m"] = m


def constrain(x, *spec_entries):
    """Apply with_sharding_constraint if a mesh is installed.

    spec_entries use tokens: "DP" (pod+data batch axes), "D", "M", None.
    Entries are right-padded with None to x.ndim; non-divisible entries are
    dropped.
    """
    mesh = _ACT["mesh"]
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    names = mesh.axis_names
    entries = []
    for dim, tok in zip(x.shape, list(spec_entries) + [None] * (x.ndim - len(spec_entries))):
        if tok == "DP":
            ax = _ACT["dp"] or (tuple(a for a in ("pod", "data")
                                      if a in names) or None)
            if isinstance(ax, tuple) and len(ax) == 1:
                ax = ax[0]
        elif tok == "D":
            ax = "data" if "data" in names else None
        elif tok == "M":
            ax = _ACT["m"] if (_ACT["m"] and _ACT["m"] in names) else None
        else:
            ax = tok
        if ax is not None:
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            # uneven sharding is allowed (GSPMD pads) as long as every shard
            # is non-empty; drop only when the dim is smaller than the axis.
            if dim < size:
                ax = None
        entries.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
