"""Calibration capture (JAX replacement for the paper's Torch hooks, App. B).

Two surfaces over the same capture forward:

* :class:`CalibrationStream` — a STREAMING accumulator. Feed it batches one
  at a time (``update``); it keeps, per MoE layer, a bounded token reservoir
  of expert-input activations X̂ and the running usage counts f. Host memory
  is ``O(L * max_tokens * d)`` no matter how many batches are streamed
  (Algorithm-R reservoir sampling once the cap is hit, with ONE shared
  replacement schedule across layers so every layer keeps the same token
  positions — deterministic under ``seed``). The plan executor consumes it
  layer by layer.
* :func:`collect` — the legacy one-shot API, now a thin wrapper that streams
  every batch through a ``CalibrationStream`` and returns the familiar
  ``{layer: LayerCalibration}`` dict.

Because JAX forwards are pure, a single-shot capture is exactly equivalent to
the paper's back-to-front layer traversal (merging layer ℓ never perturbs
activations at layers ≤ ℓ) — see DESIGN.md §3.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

import jax
import numpy as np

from repro.models.config import ModelConfig
from repro.models import model as MD


@dataclass
class LayerCalibration:
    x: np.ndarray        # [T, d] expert-layer inputs (tokens pooled)
    counts: np.ndarray   # [N] usage frequencies


class CalibrationStream:
    """Streaming per-layer activation reservoir + running expert counts.

    ``max_tokens_per_layer=None`` keeps every streamed token (the legacy
    ``collect`` behavior — unbounded); an integer cap bounds host memory.
    Beyond the cap, ``policy`` picks what survives:

    * ``"reservoir"`` (default) — Algorithm-R uniform sample over every
      streamed token (seeded, deterministic);
    * ``"head"`` — keep the FIRST cap tokens and drop the rest, exactly the
      legacy concatenate-then-truncate capture (counts keep accumulating
      over the whole stream either way).

    Tokens below the cap are kept in stream order under both policies, so
    with a cap ≥ the total token count the stream is bit-identical to the
    legacy capture.
    """

    def __init__(self, cfg: ModelConfig, params: dict,
                 max_tokens_per_layer: Optional[int] = None, seed: int = 0,
                 policy: str = "reservoir"):
        if cfg.moe is None:
            raise ValueError("calibration capture requires an MoE model")
        if policy not in ("reservoir", "head"):
            raise ValueError(f"unknown calibration policy {policy!r}")
        self.cfg = cfg
        self.cap = max_tokens_per_layer
        self.policy = policy
        self._fwd = jax.jit(
            lambda p, b: MD.forward(cfg, p, b, capture=True)[2])
        self._params = params
        self._rng = np.random.default_rng(seed)
        self._x: Optional[np.ndarray] = None      # [L, cap_or_T, d]
        # uncapped mode defers concatenation: chunks pile up here and are
        # joined once on first read (streaming B batches stays O(B), not
        # O(B^2) in host copies)
        self._chunks: List[np.ndarray] = []
        self._counts: Optional[np.ndarray] = None  # [L, N]
        self.tokens_seen = 0
        self.batches_seen = 0

    # ---- feeding ----------------------------------------------------------
    def update(self, batch: dict) -> None:
        """Run one capture forward and fold the batch into the reservoir."""
        expert_inputs, cnts = self._fwd(self._params, batch)
        xi = np.asarray(expert_inputs, np.float32)       # [L, B, S, d]
        L = xi.shape[0]
        xi = xi.reshape(L, -1, xi.shape[-1])             # [L, B*S, d]
        c = np.asarray(cnts, np.float32)                 # [L, N]
        self._counts = c if self._counts is None else self._counts + c
        self._fold(xi)
        self.tokens_seen += xi.shape[1]
        self.batches_seen += 1

    def consume(self, batches: Iterable[dict]) -> "CalibrationStream":
        for b in batches:
            self.update(b)
        return self

    def _fold(self, xi: np.ndarray) -> None:
        """Reservoir update. xi: [L, B*S, d]. The keep/replace decisions are
        drawn once per TOKEN and shared across layers, so layer ℓ's reservoir
        always holds the same token positions as layer ℓ' — the cross-layer
        alignment the budget planner's stats rely on."""
        if self.cap is None:
            self._chunks.append(xi.copy())
            return
        if self._x is None:
            self._x = np.empty((xi.shape[0], 0, xi.shape[-1]), np.float32)
        fill = min(self.cap - self._x.shape[1], xi.shape[1])
        if fill > 0:
            self._x = np.concatenate([self._x, xi[:, :fill]], axis=1)
        if self.policy == "head":
            return                            # legacy truncation: drop rest
        n_over = xi.shape[1] - fill
        if n_over <= 0:
            return
        # Algorithm R over the overflow, vectorized: the token with 0-based
        # global index g replaces a uniformly random reservoir row with prob
        # cap/(g+1). One uniform draw per token, scaled to its own [0, g+1)
        # range; duplicate targets resolve last-write-wins (NumPy fancy
        # assignment keeps the final occurrence), matching the sequential
        # later-token-overwrites semantics.
        g = self.tokens_seen + fill + np.arange(n_over)
        js = (self._rng.random(n_over) * (g + 1)).astype(np.int64)
        keep = np.flatnonzero(js < self.cap)
        if keep.size:
            self._x[:, js[keep]] = xi[:, fill + keep]

    def _materialize(self) -> np.ndarray:
        if self._chunks:
            parts = ([self._x] if self._x is not None else []) + self._chunks
            self._x = (parts[0] if len(parts) == 1
                       else np.concatenate(parts, axis=1))
            self._chunks = []
        if self._x is None:
            raise ValueError("CalibrationStream has seen no batches")
        return self._x

    # ---- consuming --------------------------------------------------------
    @property
    def n_tokens(self) -> int:
        """Tokens currently held per layer (≤ cap)."""
        held = 0 if self._x is None else int(self._x.shape[1])
        return held + sum(c.shape[1] for c in self._chunks)

    def layer(self, l: int) -> LayerCalibration:
        """Calibration view for ONE layer (the plan executor's access path)."""
        x = self._materialize()
        return LayerCalibration(x=x[l], counts=self._counts[l])

    def counts(self, l: int) -> np.ndarray:
        if self._counts is None:
            raise ValueError("CalibrationStream has seen no batches")
        return self._counts[l]

    def stats(self) -> Dict[int, np.ndarray]:
        """{layer: usage counts} — the budget planner's input."""
        if self._counts is None:
            return {}
        return {l: self._counts[l] for l in range(self._counts.shape[0])}

    def as_dict(self) -> Dict[int, LayerCalibration]:
        """Legacy ``collect``-shaped view (per-layer materialization)."""
        x = self._materialize()
        return {l: self.layer(l) for l in range(x.shape[0])}


def collect(cfg: ModelConfig, params: dict, batches: Iterable[dict],
            max_tokens_per_layer: int | None = None, seed: int = 0
            ) -> Dict[int, LayerCalibration]:
    """Returns {layer_index: LayerCalibration} for every MoE layer
    (compatibility wrapper over :class:`CalibrationStream`; ``policy='head'``
    reproduces the historical concatenate-then-truncate capture exactly)."""
    assert cfg.moe is not None, "calibration capture requires an MoE model"
    stream = CalibrationStream(cfg, params,
                               max_tokens_per_layer=max_tokens_per_layer,
                               seed=seed, policy="head")
    stream.consume(batches)
    return stream.as_dict()
