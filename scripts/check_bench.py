#!/usr/bin/env python
"""Serve-bench parity gate (was an inline CI heredoc; now runnable and
testable locally).

Usage: ``python scripts/check_bench.py [path/to/BENCH_serve.json]``

Asserts, on a BENCH_serve.json produced by ``benchmarks/serve_bench.py``:

* every bitwise-parity bit is true (fused K-step == step-at-a-time decode,
  gather == ragged dispatch, batched == serial admission);
* the int8 rows hold their top-1 parity tolerance vs the bf16 rows and
  store int8 expert tables (DESIGN.md §8), and the full-scale modeled
  expert stream clears the reduction gate;
* the speculative-decode rows (DESIGN.md §10) are token-for-token identical
  to the fused full-model reference (greedy AND temperature-0.7), every
  row's measured acceptance clears its floor (same-weights draft >= the
  self floor, merged drafts >= the above-chance floor), and the modeled
  deployment speedup at the recorded reference acceptance clears the gate —
  the acceptance/speedup numbers are re-checked against the recorded
  floors, not just the summary's *_ok booleans;
* the paged-KV rows (DESIGN.md §11) hold their contracts: the bf16 pool is
  token-for-token identical to the dense engine (trace AND duplicate-prompt
  prefix-sharing trace), the int8 pool clears its teacher-forced top-1
  tolerance, and the full-scale modeled decode KV stream clears the
  reduction gate vs dense bf16;
* the expert-parallel rows (DESIGN.md §13) are token-for-token identical
  between the forced-mesh engine and the single-device engine in every
  mode, and the full-scale modeled per-device expert stream clears the
  EP-degree x 0.8 reduction gate;
* the trace-guard counters are zero on every post-warmup row — no decode
  retraces, no implicit host transfers (DESIGN.md §9);
* the resilience counters are zero on every HAPPY-PATH row — no sheds, no
  numeric quarantines, no transient retries without an injected fault —
  while the ``faults`` section's degraded-mode row records the injected
  counts EXACTLY (observed quarantines == injected NaN poisonings, observed
  retries == injected transient failures, observed sheds == injected pool
  exhaustions), healthy slots stay bitwise identical to the fault-free run,
  the same fault seed replays the identical fault trace, and mid-trace
  snapshot/restore equals the uninterrupted run token-for-token in dense,
  paged, and speculative modes (DESIGN.md §12).

Exit code 0 when every gate passes; 1 with one line per failure otherwise.
"""
from __future__ import annotations

import json
import sys
from typing import List


def _records(d: dict):
    """(label, record) pairs for every engine row in the summary."""
    for tag in ("full", "compressed"):
        for phase, rec in d.get(tag, {}).items():
            yield f"{tag}/{phase}", rec
    for tag in ("full", "compressed"):
        rec = d.get("int8", {}).get(tag)
        if rec:
            yield f"int8/{tag}", rec
    for key, rec in d.get("spec", {}).get("rows", {}).items():
        yield f"spec/{key}", rec
    for tag in ("bf16", "int8"):
        rec = d.get("paged", {}).get(tag)
        if rec:
            yield f"paged/{tag}", rec


def check(d: dict) -> List[str]:
    """All gate violations in the summary dict (empty = pass)."""
    errs: List[str] = []
    parity = d.get("parity", {})
    for key in ("fused_vs_step_bitwise", "gather_vs_ragged_bitwise",
                "batched_vs_serial_admission_bitwise"):
        if parity.get(key) is not True:
            errs.append(f"parity.{key} is {parity.get(key)!r}, not True "
                        f"(parity={parity})")

    i8 = d.get("int8", {})
    if i8.get("parity_ok") is not True:
        errs.append(
            f"int8 top-1 match {i8.get('top1_match_full')}/"
            f"{i8.get('top1_match_compressed')} below tolerance "
            f"{i8.get('tolerance')}")
    for tag in ("full", "compressed"):
        dt = i8.get(tag, {}).get("weight_dtype")
        if dt != "int8":
            errs.append(f"int8.{tag}.weight_dtype is {dt!r}, not 'int8'")
    if i8.get("expert_stream_ok") is not True:
        errs.append(f"int8 expert-stream gate failed: "
                    f"{i8.get('modeled_full_scale')}")

    sp = d.get("spec")
    if not isinstance(sp, dict) or not sp.get("rows"):
        errs.append("spec section missing (no speculative-decode rows)")
        sp = {}
    for key in ("parity_greedy_bitwise", "parity_t07_bitwise"):
        if sp and sp.get(key) is not True:
            errs.append(f"spec.{key} is {sp.get(key)!r}, not True (the "
                        f"speculative engine must match the fused "
                        f"full-model engine token-for-token)")
    for key, rec in sp.get("rows", {}).items():
        floor = (sp.get("acceptance_floor_self", 1.0)
                 if rec.get("draft") == "int8_full"
                 else sp.get("acceptance_floor_merged", 1.0))
        acc = rec.get("acceptance_rate", 0.0)
        if acc < floor:
            errs.append(f"spec/{key}: acceptance_rate {acc} below its "
                        f"floor {floor} (draft={rec.get('draft')!r})")
    if sp:
        spd = sp.get("modeled_speedup_at_reference", 0.0)
        gate = sp.get("speedup_gate", 1.0)
        if spd < gate:
            errs.append(
                f"spec modeled speedup {spd}x at {sp.get('gate_slots')} "
                f"slots / acceptance {sp.get('reference_acceptance')} "
                f"below gate {gate}x")

    pg = d.get("paged")
    if not isinstance(pg, dict) or not pg.get("bf16"):
        errs.append("paged section missing (no paged-KV rows)")
        pg = {}
    if pg:
        if pg.get("parity_bf16_bitwise") is not True:
            errs.append(
                f"paged.parity_bf16_bitwise is "
                f"{pg.get('parity_bf16_bitwise')!r}, not True (the bf16 "
                f"paged engine must match the dense engine token-for-token)")
        if pg.get("prefix_sharing", {}).get("parity_duplicates_bitwise") \
                is not True:
            errs.append(
                f"paged prefix-sharing duplicate parity is "
                f"{pg.get('prefix_sharing', {}).get('parity_duplicates_bitwise')!r}, "
                f"not True (sharers must decode what their originals decoded)")
        top1 = pg.get("top1_match_int8_kv", 0.0)
        tol = pg.get("tolerance", 1.0)
        if top1 < tol:
            errs.append(f"paged int8-KV teacher-forced top-1 {top1} below "
                        f"tolerance {tol}")
        kv = pg.get("modeled_full_scale_kv", {})
        red = kv.get("kv_stream_reduction", 0.0)
        gate = pg.get("kv_stream_gate", 1.0)
        if red < gate:
            errs.append(f"paged KV-stream gate failed: full-scale reduction "
                        f"{red}x < {gate}x vs dense bf16 ({kv})")
        for tag, want in (("bf16", "bf16"), ("int8", "int8")):
            dt = pg.get(tag, {}).get("kv_dtype")
            if dt != want:
                errs.append(f"paged.{tag}.kv_dtype is {dt!r}, not {want!r}")

    for label, rec in _records(d):
        for c in ("retraces", "implicit_transfers"):
            v = rec.get(c, 0)
            if v:
                errs.append(f"{label}: counters[{c!r}] == {v}, expected 0 "
                            f"(steady-state purity regression)")
        for c in ("shed", "quarantined", "transient_retries"):
            v = rec.get(c, 0)
            if v:
                errs.append(f"{label}: counters[{c!r}] == {v}, expected 0 "
                            f"(happy-path row shed/quarantined/retried "
                            f"without an injected fault, DESIGN.md §12)")

    ep = d.get("ep")
    if not isinstance(ep, dict) or not ep.get("modes"):
        errs.append("ep section missing (no expert-parallel serving rows, "
                    "DESIGN.md §13)")
        ep = {}
    if ep:
        for mode, rec in ep.get("modes", {}).items():
            if rec.get("parity_bitwise") is not True:
                errs.append(
                    f"ep/{mode}: parity_bitwise is "
                    f"{rec.get('parity_bitwise')!r}, not True (the "
                    f"{ep.get('mesh')} mesh engine must match the "
                    f"single-device engine token-for-token)")
        fs = ep.get("full_scale", {})
        red = fs.get("expert_stream_reduction", 0.0)
        gate = ep.get("expert_stream_gate", 1.0)
        if red < gate:
            errs.append(
                f"ep expert-stream gate failed: modeled per-device "
                f"reduction {red}x < {gate}x at EP={fs.get('ep_degree')} "
                f"(full_scale={fs})")

    ft = d.get("faults")
    if not isinstance(ft, dict) or "observed" not in ft:
        errs.append("faults section missing (no degraded-mode "
                    "fault-injection row, DESIGN.md §12)")
        ft = {}
    if ft:
        inj, obs = ft.get("injected", {}), ft.get("observed", {})
        for got, want in (("quarantined", "nan_logits"),
                          ("shed", "exhaust"),
                          ("transient_retries", "transient_fails")):
            if obs.get(got) != inj.get(want):
                errs.append(
                    f"faults: observed[{got!r}] == {obs.get(got)!r} but "
                    f"injected[{want!r}] == {inj.get(want)!r} — degraded-"
                    f"mode accounting must record injected faults EXACTLY")
        if ft.get("accounting_exact") is not True:
            errs.append(f"faults.accounting_exact is "
                        f"{ft.get('accounting_exact')!r}, not True "
                        f"(statuses={ft.get('statuses')}, "
                        f"shed_reasons={ft.get('shed_reasons')})")
        for key in ("healthy_parity_bitwise", "quarantined_prefix_of_clean",
                    "clean_run_counters_zero", "replay_digest_equal",
                    "replay_tokens_bitwise"):
            if ft.get(key) is not True:
                errs.append(f"faults.{key} is {ft.get(key)!r}, not True")
        for c in ("retraces", "implicit_transfers"):
            if ft.get(c, 0):
                errs.append(f"faults: counters[{c!r}] == {ft.get(c)} under "
                            f"injected faults, expected 0 (fault handling "
                            f"must not break the hot-loop contract)")
        restore = ft.get("restore", {})
        for mode in ("dense", "paged", "spec"):
            if restore.get(mode) is not True:
                errs.append(
                    f"faults.restore[{mode!r}] is {restore.get(mode)!r}, "
                    f"not True (mid-trace snapshot/restore must finish "
                    f"token-for-token identical to the uninterrupted run)")
    return errs


def main(argv=None) -> int:
    args = sys.argv[1:] if argv is None else argv
    path = args[0] if args else "benchmarks/BENCH_serve.json"
    with open(path) as f:
        d = json.load(f)
    errs = check(d)
    for e in errs:
        print(f"check_bench FAIL: {e}")
    if errs:
        return 1
    i8 = d["int8"]
    print("serve-bench parity OK:", d["parity"])
    print("int8 parity-tolerance OK:", i8["top1_match_full"],
          i8["top1_match_compressed"], ">=", i8["tolerance"])
    print("int8 expert-stream gate OK (>=", i8["expert_stream_gate"],
          "x vs bf16 M=N/2)")
    sp = d["spec"]
    print("spec parity OK: greedy and t0.7 bitwise vs the fused reference")
    print("spec acceptance OK:",
          {k: r["acceptance_rate"] for k, r in sp["rows"].items()},
          "(self floor", sp["acceptance_floor_self"],
          "/ merged floor", sp["acceptance_floor_merged"], ")")
    print("spec modeled-speedup gate OK:",
          sp["modeled_speedup_at_reference"], "x >=", sp["speedup_gate"],
          "x at", sp["gate_slots"], "slots / acceptance",
          sp["reference_acceptance"])
    pg = d["paged"]
    print("paged-KV parity OK: bf16 bitwise vs dense; int8-KV top-1",
          pg["top1_match_int8_kv"], ">=", pg["tolerance"])
    print("paged KV-stream gate OK:",
          pg["modeled_full_scale_kv"]["kv_stream_reduction"], "x >=",
          pg["kv_stream_gate"], "x vs dense bf16; prefix hit rate",
          pg["prefix_sharing"]["hit_rate"])
    ep = d["ep"]
    print("EP parity OK:", ep["mesh"], "mesh bitwise vs single device in",
          sorted(ep["modes"]))
    print("EP expert-stream gate OK:",
          ep["full_scale"]["expert_stream_reduction"], "x >=",
          ep["expert_stream_gate"], "x at EP=",
          ep["full_scale"]["ep_degree"])
    print("trace-guard counters OK: 0 retraces / 0 implicit transfers "
          "across", len(list(_records(d))), "rows")
    ft = d["faults"]
    print("resilience counters OK: 0 sheds / quarantines / retries on "
          "every happy-path row")
    print("fault-injection OK: injected", ft["injected"], "-> observed",
          ft["observed"], "exactly; healthy slots bitwise; same-seed "
          "replay digest", ft["fault_trace_digest"][:16])
    print("snapshot/restore OK:", ft["restore"],
          "(token-for-token vs uninterrupted)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
