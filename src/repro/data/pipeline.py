"""Data pipeline: deterministic synthetic LM stream + file-backed token
shards, with checkpointable iteration state and per-host sharding hooks.

Design for 1000+ nodes: each host owns ``host_id``-strided shards of the
global batch; ``state()``/``restore()`` round-trip the cursor so a restart
(or an elastic re-shard onto a different host count) resumes mid-epoch
without data repetition. This container is single-host, but the host-count
parameters are honored throughout and unit-tested with >1 logical hosts.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataState:
    step: int = 0
    epoch: int = 0
    cursor: int = 0          # token offset within the corpus (file-backed)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(s: str) -> "DataState":
        return DataState(**json.loads(s))


class SyntheticLM:
    """Deterministic synthetic token stream: a hash-seeded Markov-ish mix of
    repeated n-grams and noise — enough structure that a model visibly learns
    (loss drops below uniform) while requiring no data files."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1):
        assert global_batch % num_hosts == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // num_hosts
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._state = DataState()
        # small "phrase book" shared across hosts: structure to learn
        pb_rng = np.random.default_rng(seed)
        self.phrases = pb_rng.integers(
            0, vocab_size, size=(64, 8)).astype(np.int32)

    def state(self) -> DataState:
        return dataclasses.replace(self._state)

    def restore(self, st: DataState) -> None:
        self._state = dataclasses.replace(st)

    def _gen(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.seed, step, self.host_id))
        out = np.empty((self.local_batch, self.seq), np.int32)
        for b in range(self.local_batch):
            toks = []
            while len(toks) < self.seq:
                if rng.random() < 0.7:
                    toks.extend(self.phrases[rng.integers(len(self.phrases))])
                else:
                    toks.extend(rng.integers(0, self.vocab, size=4))
            out[b] = np.asarray(toks[:self.seq], np.int32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = {"tokens": self._gen(self._state.step)}
        self._state.step += 1
        return batch


class TokenFileDataset:
    """Binary token shards (int32 .bin files) packed into [B, S] batches.

    Hosts stride the corpus: host h reads sequences h, h+H, h+2H, ... so the
    union over hosts is exactly the corpus order.
    """

    def __init__(self, paths, seq_len: int, global_batch: int,
                 host_id: int = 0, num_hosts: int = 1):
        assert global_batch % num_hosts == 0
        self.tokens = np.concatenate(
            [np.fromfile(p, dtype=np.int32) for p in sorted(map(str, paths))])
        self.seq = seq_len
        self.local_batch = global_batch // num_hosts
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._state = DataState()
        self.n_seqs = len(self.tokens) // seq_len

    def state(self) -> DataState:
        return dataclasses.replace(self._state)

    def restore(self, st: DataState) -> None:
        self._state = dataclasses.replace(st)

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        out = np.empty((self.local_batch, self.seq), np.int32)
        for i in range(self.local_batch):
            global_idx = (self._state.cursor + self.host_id
                          + i * self.num_hosts)
            seq_idx = global_idx % self.n_seqs
            if global_idx and seq_idx < self.num_hosts:
                self._state.epoch += 1
            s = seq_idx * self.seq
            out[i] = self.tokens[s:s + self.seq]
        self._state.cursor += self.local_batch * self.num_hosts
        self._state.step += 1
        return {"tokens": out}


def write_token_file(path, tokens: np.ndarray) -> None:
    Path(path).parent.mkdir(parents=True, exist_ok=True)
    np.asarray(tokens, np.int32).tofile(str(path))


def make_pipeline(cfg, seq_len: int, global_batch: int, *,
                  data_dir: Optional[str] = None, seed: int = 0,
                  host_id: int = 0, num_hosts: int = 1):
    if data_dir:
        paths = sorted(Path(data_dir).glob("*.bin"))
        if paths:
            return TokenFileDataset(paths, seq_len, global_batch,
                                    host_id=host_id, num_hosts=num_hosts)
    return SyntheticLM(cfg.vocab_size, seq_len, global_batch, seed=seed,
                       host_id=host_id, num_hosts=num_hosts)
